"""Fault tolerance for the training runtime.

Three cooperating pieces, all exercised in tests and the e2e example:

- **FailureInjector** — deterministic pseudo-random "node failure" events
  (exception raised between steps), standing in for a real healthd signal.
- **ElasticMesh** — rebuilds the largest usable mesh from the surviving
  device count (drops data-parallel rows first, preserving the model axis
  so parameter shards stay materialisable), and re-places a checkpointed
  state onto it.
- **run_resilient** — the restart loop: step -> (maybe) checkpoint ->
  on failure: rebuild mesh, re-lower the step, restore latest checkpoint,
  continue.  Training is bit-deterministic across restarts because the
  data pipeline is a pure function of (seed, step).

Straggler mitigation lives at two levels: the middleware executor
duplicates tail tasks (core/executor.py), and ``StragglerMonitor`` here
flags slow steps from a rolling median for the training loop to act on
(re-dispatch / exclude a worker at real scale; logged on CPU).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Sequence

import numpy as np

log = logging.getLogger("repro.fault")


class NodeFailure(RuntimeError):
    """Simulated loss of part of the allocation."""

    def __init__(self, lost_devices: int):
        super().__init__(f"lost {lost_devices} devices")
        self.lost_devices = lost_devices


@dataclasses.dataclass
class FailureInjector:
    """Raise a NodeFailure with probability ``rate`` per step (seeded)."""

    rate: float = 0.0
    seed: int = 0
    lost_per_event: int = 1
    _rng: np.random.Generator = dataclasses.field(init=False, repr=False)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def check(self, step: int):
        if self.rate > 0 and self._rng.random() < self.rate:
            raise NodeFailure(self.lost_per_event)


@dataclasses.dataclass
class ElasticMesh:
    """Track surviving devices; rebuild (data, model) meshes after loss.

    The model axis is preserved (param shards must still fit); whole
    data-parallel rows are dropped, so the new mesh uses
    ``floor(devices / model) * model`` devices.
    """

    model_axis: int
    devices: Sequence = ()

    def __post_init__(self):
        import jax
        if not self.devices:
            self.devices = tuple(jax.devices())

    def usable(self, survivors: int) -> tuple[int, int]:
        rows = survivors // self.model_axis
        if rows < 1:
            raise RuntimeError("not enough devices for one model replica")
        return rows, self.model_axis

    def make(self, survivors: int | None = None):
        import jax
        from jax.sharding import Mesh
        n = survivors if survivors is not None else len(self.devices)
        rows, cols = self.usable(n)
        devs = np.asarray(self.devices[: rows * cols]).reshape(rows, cols)
        return Mesh(devs, ("data", "model"))


class StragglerMonitor:
    """Rolling-median step-time watchdog.

    ``observe`` returns True when a step exceeds ``threshold`` x the median
    of the last ``window`` steps — the signal a real deployment uses to
    re-dispatch work away from a slow host (here: logged + counted).
    """

    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times: list[float] = []
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) < 8:
            return False
        med = float(np.median(hist))
        if dt > self.threshold * med:
            self.flagged += 1
            log.warning("straggler step: %.4fs vs median %.4fs", dt, med)
            return True
        return False


def run_resilient(*, total_steps: int, build: Callable, step_fn_state,
                  injector: FailureInjector, ckpt_manager,
                  restore: Callable, start_step: int = 0):
    """Generic restart loop.

    build(survivors) -> (step_callable, state) re-lowers after a failure;
    restore(step) -> state reloads the latest checkpoint.  Returns
    (state, history) where history records failures and restarts.
    """
    step_fn, state = step_fn_state
    survivors = None
    history = {"failures": 0, "restarts": [], "stragglers": 0}
    monitor = StragglerMonitor()
    s = start_step
    while s < total_steps:
        try:
            injector.check(s)
            t0 = time.perf_counter()
            state = step_fn(state, s)
            monitor.observe(time.perf_counter() - t0)
            ckpt_manager.maybe_save(state, s)
            s += 1
        except NodeFailure as e:
            history["failures"] += 1
            survivors = (survivors if survivors is not None
                         else e.lost_devices + 0) or 0
            log.warning("failure at step %d (%s); rebuilding", s, e)
            step_fn, _ = build(e.lost_devices)
            latest = ckpt_manager_latest(ckpt_manager)
            if latest is not None:
                state = restore(latest)
                s = latest + 1
            history["restarts"].append(s)
    history["stragglers"] = monitor.flagged
    ckpt_manager.wait()
    return state, history


def ckpt_manager_latest(mgr):
    from repro.checkpoint import latest_step
    mgr.wait()
    return latest_step(mgr.directory)

"""Fault tolerance for the training runtime.

Three cooperating pieces, all exercised in tests and the e2e example:

- **FailureInjector** — deterministic pseudo-random "node failure" events
  (exception raised between steps), standing in for a real healthd signal.
- **ElasticMesh** — rebuilds the largest usable mesh from the surviving
  device count (drops data-parallel rows first, preserving the model axis
  so parameter shards stay materialisable), and re-places a checkpointed
  state onto it.
- **run_resilient** — the restart loop: step -> (maybe) checkpoint ->
  on failure: rebuild mesh, re-lower the step, restore latest checkpoint,
  continue.  Training is bit-deterministic across restarts because the
  data pipeline is a pure function of (seed, step).

Straggler mitigation lives at two levels: the middleware executor
duplicates tail tasks (core/executor.py), and ``StragglerMonitor`` here
flags slow steps from a rolling median for the training loop to act on
(re-dispatch / exclude a worker at real scale; logged on CPU).
"""

from __future__ import annotations

import dataclasses
import logging
import math
import time
import zlib
from typing import Callable, Sequence

import numpy as np

log = logging.getLogger("repro.fault")


@dataclasses.dataclass(frozen=True)
class FaultOptions:
    """Failure injection + recovery policy for the scheduling stack.

    Passed to ``simulate()`` / ``RealExecutor.run()`` (and through them to
    ``SchedEngine``).  With the default (all rates zero, empty trace) the
    options are *disabled* and every consumer treats them exactly like
    ``None`` — dispatch traces stay bit-identical.

    Failure injection (seeded, substrate-independent):

    - ``node_failure_rate`` — stochastic per-node-per-second hazard; the
      fleet-wide failure process is Poisson with rate
      ``rate x total_nodes``, victims drawn uniformly.
    - ``node_failure_trace`` — trace-driven ``(time, pool_name, node)``
      events, merged with the stochastic stream in time order.
    - ``task_failure_prob`` — per-attempt software-failure probability;
      the failing attempt dies at a seeded fraction of its duration.
      Attempts beyond ``max_task_retries`` always succeed (runaway guard).
    - ``node_recovery_time`` — a failed node rejoins after this many
      modelled seconds (``inf`` = permanent loss).

    Recovery policy:

    - ``recovery`` — ``"arbitrated"`` prices restart-from-checkpoint vs.
      re-run-from-scratch per set (and decides per set whether paying the
      checkpoint write overhead is worth it, from the live hazard
      estimate); ``"restart"`` / ``"rerun"`` force the pure arms.
    - ``checkpoint_interval`` — modelled seconds of task progress between
      snapshots (0 disables checkpointing entirely).
    - ``checkpoint_write_cost`` / ``checkpoint_read_cost`` — base I/O cost
      per snapshot; reads additionally pay the ``Allocation.transfer``
      distance from the writer's placement to the restarted attempt's.
    - ``replicate`` — proactively duplicate at-risk tasks (failure
      probability before completion above ``replicate_risk``) onto
      another node via the speculation machinery; if the primary's node
      dies the replica is promoted and no work is lost.
    - ``hazard_aware`` — fold the failure hazard into the predictor's
      residual bound (re-predictions stay honest under faults).
    """

    node_failure_rate: float = 0.0
    node_failure_trace: tuple = ()
    task_failure_prob: float = 0.0
    node_recovery_time: float = math.inf
    seed: int = 0
    recovery: str = "arbitrated"
    checkpoint_interval: float = 0.0
    checkpoint_write_cost: float = 0.0
    checkpoint_read_cost: float = 0.0
    max_task_retries: int = 4
    replicate: bool = False
    replicate_risk: float = 0.35
    hazard_aware: bool = True

    def __post_init__(self):
        if self.recovery not in ("arbitrated", "rerun", "restart"):
            raise ValueError(f"unknown recovery policy {self.recovery!r}")

    @property
    def enabled(self) -> bool:
        return (self.node_failure_rate > 0.0
                or bool(self.node_failure_trace)
                or self.task_failure_prob > 0.0)


class FailureSchedule:
    """Deterministic failure stream shared by both substrates.

    ``next_node_failure()`` yields ``(time, pool_index, node)`` events in
    time order, merging the trace with a seeded Poisson stream; the stream
    depends only on ``(opts.seed, sites)``, never on when the caller asks,
    so the simulator and the real executor see identical schedules.

    ``attempt_failure(name, i, attempt)`` is keyed purely on the task
    identity + attempt number (stable CRC of the set name), so per-attempt
    draws are independent of substrate dispatch order too.
    """

    def __init__(self, opts: FaultOptions, sites: Sequence[tuple[int, int]],
                 pool_names: Sequence[str]):
        self.opts = opts
        #: flat (pool_index, node) list of every failure site
        self._sites = [(k, n) for k, count in sites for n in range(count)]
        name_to_idx = {name: k for k, name in enumerate(pool_names)}
        trace = []
        for t, pool_name, node in opts.node_failure_trace:
            if pool_name not in name_to_idx:
                raise ValueError(f"unknown pool in failure trace: "
                                 f"{pool_name!r}")
            trace.append((float(t), name_to_idx[pool_name], int(node)))
        self._trace = sorted(trace)
        self._trace_pos = 0
        self._rng = np.random.default_rng((opts.seed, 0xFA01))
        self._t = 0.0  # internal stochastic clock

    def _next_stochastic(self) -> tuple[float, int, int] | None:
        rate = self.opts.node_failure_rate * len(self._sites)
        if rate <= 0.0 or not self._sites:
            return None
        self._t += float(self._rng.exponential(1.0 / rate))
        k, n = self._sites[int(self._rng.integers(len(self._sites)))]
        return (self._t, k, n)

    def next_node_failure(self) -> tuple[float, int, int] | None:
        """Pop the next (time, pool_index, node) event, or None."""
        trace_ev = (self._trace[self._trace_pos]
                    if self._trace_pos < len(self._trace) else None)
        if self._stoch_peek is None:
            self._stoch_peek = self._next_stochastic()
        stoch_ev = self._stoch_peek
        if trace_ev is None and stoch_ev is None:
            return None
        if stoch_ev is None or (trace_ev is not None
                                and trace_ev[0] <= stoch_ev[0]):
            self._trace_pos += 1
            return trace_ev
        self._stoch_peek = None
        return stoch_ev

    _stoch_peek: tuple[float, int, int] | None = None

    def attempt_failure(self, name: str, i: int, attempt: int) \
            -> float | None:
        """Does attempt #``attempt`` of task (name, i) fail?  Returns the
        fraction of its duration at which it dies, or None."""
        p = self.opts.task_failure_prob
        if p <= 0.0 or attempt >= self.opts.max_task_retries:
            return None
        rng = np.random.default_rng(
            (self.opts.seed, 0xFA02, zlib.crc32(name.encode()), i, attempt))
        if rng.random() >= p:
            return None
        return 0.05 + 0.9 * float(rng.random())


class NodeFailure(RuntimeError):
    """Simulated loss of part of the allocation."""

    def __init__(self, lost_devices: int):
        super().__init__(f"lost {lost_devices} devices")
        self.lost_devices = lost_devices


@dataclasses.dataclass
class FailureInjector:
    """Raise a NodeFailure with probability ``rate`` per step (seeded)."""

    rate: float = 0.0
    seed: int = 0
    lost_per_event: int = 1
    _rng: np.random.Generator = dataclasses.field(init=False, repr=False)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def check(self, step: int):
        if self.rate > 0 and self._rng.random() < self.rate:
            raise NodeFailure(self.lost_per_event)


@dataclasses.dataclass
class ElasticMesh:
    """Track surviving devices; rebuild (data, model) meshes after loss.

    The model axis is preserved (param shards must still fit); whole
    data-parallel rows are dropped, so the new mesh uses
    ``floor(devices / model) * model`` devices.
    """

    model_axis: int
    devices: Sequence = ()

    def __post_init__(self):
        import jax
        if not self.devices:
            self.devices = tuple(jax.devices())

    def usable(self, survivors: int) -> tuple[int, int]:
        rows = survivors // self.model_axis
        if rows < 1:
            raise RuntimeError("not enough devices for one model replica")
        return rows, self.model_axis

    def make(self, survivors: int | None = None):
        import jax
        from jax.sharding import Mesh
        n = survivors if survivors is not None else len(self.devices)
        rows, cols = self.usable(n)
        devs = np.asarray(self.devices[: rows * cols]).reshape(rows, cols)
        return Mesh(devs, ("data", "model"))


class StragglerMonitor:
    """Rolling-median step-time watchdog.

    ``observe`` returns True when a step exceeds ``threshold`` x the median
    of the last ``window`` steps — the signal a real deployment uses to
    re-dispatch work away from a slow host (here: logged + counted).
    """

    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times: list[float] = []
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) < 8:
            return False
        med = float(np.median(hist))
        if dt > self.threshold * med:
            self.flagged += 1
            log.warning("straggler step: %.4fs vs median %.4fs", dt, med)
            return True
        return False


def run_resilient(*, total_steps: int, build: Callable, step_fn_state,
                  injector: FailureInjector, ckpt_manager,
                  restore: Callable, start_step: int = 0):
    """Generic restart loop.

    build(survivors) -> (step_callable, state) re-lowers after a failure;
    restore(step) -> state reloads the latest checkpoint.  Returns
    (state, history) where history records failures and restarts.
    """
    step_fn, state = step_fn_state
    survivors = None
    history = {"failures": 0, "restarts": [], "stragglers": 0}
    monitor = StragglerMonitor()
    s = start_step
    while s < total_steps:
        try:
            injector.check(s)
            t0 = time.perf_counter()
            state = step_fn(state, s)
            monitor.observe(time.perf_counter() - t0)
            ckpt_manager.maybe_save(state, s)
            s += 1
        except NodeFailure as e:
            history["failures"] += 1
            survivors = (survivors if survivors is not None
                         else e.lost_devices + 0) or 0
            log.warning("failure at step %d (%s); rebuilding", s, e)
            step_fn, _ = build(e.lost_devices)
            latest = ckpt_manager_latest(ckpt_manager)
            if latest is not None:
                state = restore(latest)
                s = latest + 1
            history["restarts"].append(s)
    history["stragglers"] = monitor.flagged
    ckpt_manager.wait()
    return state, history


def ckpt_manager_latest(mgr):
    from repro.checkpoint import latest_step
    mgr.wait()
    return latest_step(mgr.directory)

"""Distributed runtime: sharding rules, step builders, fault tolerance.

`steps` / `fault` are exported lazily to avoid a circular import (model
modules import `runtime.sharding` at definition time).
"""

from .sharding import (DEFAULT_RULES, ShardingRules, current_mesh,
                       current_rules, shard_act, use_sharding)

_LAZY = {
    "TrainOptions": "steps", "TrainState": "steps",
    "abstract_train_state": "steps", "batch_shardings": "steps",
    "build_decode_step": "steps", "build_prefill_step": "steps",
    "build_train_step": "steps", "make_train_state": "steps",
    "state_shardings": "steps", "cache_shardings": "steps",
    "ElasticMesh": "fault", "FailureInjector": "fault",
    "FailureSchedule": "fault", "FaultOptions": "fault",
    "NodeFailure": "fault", "StragglerMonitor": "fault",
    "run_resilient": "fault",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(name)

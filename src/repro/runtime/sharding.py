"""Logical-axis sharding engine.

Every parameter and strategic activation in the framework is annotated with
*logical* axis names ("embed", "ffn", "heads", "vocab", "experts", "batch",
"seq", ...).  A :class:`ShardingRules` table maps logical names to mesh
axes; `spec_for` resolves a logical shape to a `PartitionSpec`, silently
dropping assignments that do not divide the dimension (e.g. qwen2-0.5b's 14
heads on a 16-way model axis) — the dimension is then left unsharded and
ZeRO/FSDP sharding on the other dims keeps memory in check.

The rules are data, not code: the §Perf hillclimb swaps rule tables per
architecture without touching model definitions.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


#: default logical-axis -> mesh-axis assignments (single- and multi-pod).
#: entries may be a single mesh axis or a tuple (sharded over both).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),                      # sequence replicated in train_4k
    "seq_shard": ("model",),        # explicit SP/context parallelism
    "act_embed": (),
    "act_ffn": ("model",),
    "act_heads": ("model",),
    "act_vocab": ("model",),
    "flash_heads": (),              # head sharding inside the flash scan
    "flash_kv": (),
    # parameters (2D: FSDP over data, TP over model)
    "embed": ("data",),             # ZeRO-3 / FSDP shard
    "ffn": ("model",),
    "heads": ("model",),
    "kv_heads": (),
    "qkv_out": (),                  # fused q/k/v output dim when heads unshardable
    "vocab": ("model",),
    "experts": ("model",),
    "expert_ffn": (),
    "layers": (),
    "ssm_state": (),
    "conv": (),
    "cache_seq": ("model",),        # decode KV cache sharded along sequence
    "cache_batch": ("pod", "data"),
    "pos": (),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping of logical axis names to mesh axes."""

    table: Mapping[str, tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))

    def override(self, **kw: Sequence[str] | str | None) -> "ShardingRules":
        t = dict(self.table)
        for k, v in kw.items():
            if v is None:
                t[k] = ()
            elif isinstance(v, str):
                t[k] = (v,)
            else:
                t[k] = tuple(v)
        return ShardingRules(t)

    def mesh_axes_for(self, logical: str | None, mesh: Mesh) -> tuple[str, ...]:
        if logical is None:
            return ()
        axes = self.table.get(logical, ())
        return tuple(a for a in axes if a in mesh.axis_names)

    def spec_for(self, logical_axes: Sequence[str | None], shape: Sequence[int],
                 mesh: Mesh) -> P:
        """PartitionSpec for a tensor, enforcing divisibility and uniqueness
        (a mesh axis may shard at most one dim)."""
        used: set[str] = set()
        entries = []
        for dim, logical in zip(shape, logical_axes):
            axes = self.mesh_axes_for(logical, mesh)
            axes = tuple(a for a in axes if a not in used)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if axes and size > 0 and dim % size == 0:
                used.update(axes)
                entries.append(axes if len(axes) > 1 else axes[0])
            else:
                entries.append(None)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding_for(self, logical_axes: Sequence[str | None],
                     shape: Sequence[int], mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.spec_for(logical_axes, shape, mesh))


# ---------------------------------------------------------------------------
# Ambient context: models call shard_act(...) without threading mesh/rules.
# ---------------------------------------------------------------------------

class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: ShardingRules = ShardingRules()
        self.flags: dict = {}


_CTX = _Ctx()


class use_sharding:
    """Context manager installing (mesh, rules, perf flags) for
    shard_act / specs.  ``flags`` gates perf-variant code paths (§Perf
    hillclimb), e.g. {"moe_gather_bf16": True, "sharded_decode": True}."""

    def __init__(self, mesh: Mesh | None, rules: ShardingRules | None = None,
                 flags: dict | None = None):
        self.mesh = mesh
        self.rules = rules or ShardingRules()
        self.flags = flags or {}

    def __enter__(self):
        self._prev = (_CTX.mesh, _CTX.rules, _CTX.flags)
        _CTX.mesh, _CTX.rules, _CTX.flags = self.mesh, self.rules, self.flags
        return self

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules, _CTX.flags = self._prev
        return False


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def current_rules() -> ShardingRules:
    return _CTX.rules


def current_flags() -> dict:
    return _CTX.flags


def shard_act(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Constrain an activation's sharding by logical axes (no-op without an
    ambient mesh, so single-device smoke tests never see collectives)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = _CTX.rules.spec_for(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def gathered(w: jax.Array, *logical_axes: str | None, dtype=None) -> jax.Array:
    """§Perf flag ``zero3_gather``: explicit ZeRO-3 weight gather.

    Cast the weight to compute dtype (bf16 — half the gather bytes) and
    constrain it to its MODEL-only sharding right before use.  XLA then
    inserts one cheap bf16 all-gather over the FSDP ('pod'/'data') axes
    and the matmul contracts an unsharded dim — instead of partial-summing
    and all-reducing [B, S, D]-sized ACTIVATIONS on every matmul (the
    dominant traffic in the llama4 train baseline).  Gradients flow back
    through the constraint as a reduce-scatter.  No-op unless the flag is
    set, so smoke tests and default paths are unchanged.
    """
    out = w if dtype is None else w.astype(dtype)
    mesh = _CTX.mesh
    if mesh is None or not _CTX.flags.get("zero3_gather"):
        return out
    if _CTX.flags.get("zero3_full"):
        # full DP compute: gather over every axis (weights transit bf16)
        return jax.lax.with_sharding_constraint(
            out, NamedSharding(mesh, P()))
    entries = []
    used: set[str] = set()
    for dim, la in zip(w.shape, logical_axes):
        axes = tuple(a for a in _CTX.rules.mesh_axes_for(la, mesh)
                     if a == "model" and a not in used)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and dim % size == 0:
            used.update(axes)
            entries.append(axes if len(axes) > 1 else axes[0])
        else:
            entries.append(None)
    return jax.lax.with_sharding_constraint(
        out, NamedSharding(mesh, P(*entries)))

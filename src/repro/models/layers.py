"""Shared model building blocks: norms, RoPE (incl. partial + M-RoPE),
embeddings, and SwiGLU MLPs.  Pure functions over parameter pytrees."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime.sharding import gathered, shard_act
from .config import ModelConfig
from .params import spec

COMPUTE_DTYPE = jnp.bfloat16


def rms_norm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return y.astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) +
            bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rope_pct: float, theta: float):
    rot_dim = int(head_dim * rope_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                           / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, *, theta: float, rope_pct: float = 1.0,
               mrope_sections: tuple[int, ...] = ()):
    """x: [B, S, H, D].  positions: [B, S] or, for M-RoPE, [3, B, S]
    (temporal / height / width position ids, qwen2-vl §2.1).
    """
    d = x.shape[-1]
    inv, rot_dim = rope_freqs(d, rope_pct, theta)
    half = rot_dim // 2
    if mrope_sections:
        assert sum(mrope_sections) == half, (mrope_sections, half)
        # each frequency band uses the position channel of its section
        section_of = jnp.repeat(
            jnp.arange(len(mrope_sections)),
            jnp.asarray(mrope_sections),
            total_repeat_length=half)                     # [half]
        pos = positions.astype(jnp.float32)               # [3, B, S]
        all_angles = pos[..., None] * inv[None, None, None, :]  # [3,B,S,half]
        pick = jax.nn.one_hot(section_of, len(mrope_sections),
                              dtype=jnp.float32)          # [half, 3]
        angles = jnp.einsum("cbsh,hc->bsh", all_angles, pick)
    else:
        pos = positions.astype(jnp.float32)               # [B, S]
        angles = pos[..., None] * inv[None, None, :]      # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]                  # [B, S, 1, half]
    sin = jnp.sin(angles)[:, :, None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    xr = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([xr.astype(x.dtype), x_pass], axis=-1)


def sinusoidal_positions(seq: int, d: int):
    return sinusoidal_at(jnp.arange(seq, dtype=jnp.int32), d)


def sinusoidal_at(positions, d: int):
    """Sinusoidal embeddings at arbitrary positions (any leading shape)."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    angle = pos / (10000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)],
                           axis=-1).astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, layers: int, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    L = (layers,)
    return {
        "gate": spec(L + (d, f), ("layers", "embed", "ffn")),
        "up": spec(L + (d, f), ("layers", "embed", "ffn")),
        "down": spec(L + (f, d), ("layers", "ffn", "embed")),
    }


def swiglu(p, x):
    """p holds per-layer slices (no leading L dim at call time)."""
    g = gathered(p["gate"], "embed", "ffn", dtype=x.dtype)
    u = gathered(p["up"], "embed", "ffn", dtype=x.dtype)
    d = gathered(p["down"], "ffn", "embed", dtype=x.dtype)
    h = jax.nn.silu(x @ g) * (x @ u)
    h = shard_act(h, "batch", None, "act_ffn")
    return h @ d


def gelu_mlp(p, x):
    h = jax.nn.gelu(x @ p["fc1"].astype(x.dtype) + p["b1"].astype(x.dtype))
    return h @ p["fc2"].astype(x.dtype) + p["b2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig):
    out = {"embedding": spec((cfg.vocab_size, cfg.d_model),
                             ("vocab", "embed"), scale=0.02)}
    if not cfg.tie_embeddings:
        out["lm_head"] = spec((cfg.d_model, cfg.vocab_size),
                              ("embed", "vocab"))
    return out


def embed(params, tokens, cfg: ModelConfig):
    x = params["embedding"].astype(COMPUTE_DTYPE)[tokens]
    return shard_act(x * cfg.embed_scale, "batch", "seq", "act_embed")


def unembed(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = params["embedding"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    logits = (x @ w) * cfg.logit_scale
    return shard_act(logits, "batch", "seq", "act_vocab")


def cross_entropy(logits, labels, ignore_id: int = -1):
    """Mean token NLL in fp32; labels == ignore_id are masked."""
    lf = logits.astype(jnp.float32)
    m = lf.max(axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    valid = (labels != ignore_id).astype(jnp.float32)
    return (nll * valid).sum() / jnp.maximum(valid.sum(), 1.0)

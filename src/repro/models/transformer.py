"""Decoder-only transformer covering the dense, MoE and VLM families
(qwen2-0.5b, minicpm-2b, h2o-danube, stablelm-12b, qwen3-moe, llama4-scout,
qwen2-vl).

Layers are *scanned* (stacked parameters with a leading L dim) so the HLO —
and hence dry-run compile time at 512 devices — stays O(1) in depth.
Architectures with a periodic layer pattern (llama4: every ``global_every``-th
layer is global-attention NoPE, the rest chunked-local RoPE) are scanned in
groups of ``global_every`` with the heterogeneous layer unrolled inside the
group body.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime.sharding import shard_act
from .attention import (attention_specs, cache_shape, decode_attention,
                        layer_mask_kind, self_attention)
from .config import ModelConfig
from .layers import (COMPUTE_DTYPE, cross_entropy, embed, embed_specs,
                     mlp_specs, rms_norm, swiglu, unembed)
from .moe import moe_block, moe_specs
from .params import spec


def transformer_specs(cfg: ModelConfig):
    L = cfg.num_layers
    blocks = {
        "ln1": spec((L, cfg.d_model), ("layers", "embed"), init="ones"),
        "ln2": spec((L, cfg.d_model), ("layers", "embed"), init="ones"),
        "attn": attention_specs(cfg, L),
    }
    if cfg.family == "moe":
        blocks["moe"] = moe_specs(cfg, L)
    else:
        blocks["mlp"] = mlp_specs(cfg, L)
    return {
        **embed_specs(cfg),
        "blocks": blocks,
        "final_norm": spec((cfg.d_model,), ("embed",), init="ones"),
    }


def _layer_params(p, idx):
    """Slice one layer's parameters out of the stacked tree."""
    return jax.tree.map(lambda a: a[idx], p)


def _block(p, x, cfg: ModelConfig, positions, layer_idx: int, aux):
    """One transformer block (pre-norm).  layer_idx is static."""
    mk = layer_mask_kind(cfg, layer_idx)
    h = rms_norm(x, p["ln1"].astype(jnp.float32), cfg.norm_eps)
    h = self_attention(p["attn"], h, cfg, positions, **mk)
    x = x + h * cfg.residual_scale
    h = rms_norm(x, p["ln2"].astype(jnp.float32), cfg.norm_eps)
    if cfg.family == "moe":
        h, a = moe_block(p["moe"], h, cfg)
        aux = aux + a
    else:
        h = swiglu(p["mlp"], h)
    x = x + h * cfg.residual_scale
    x = shard_act(x, "batch", "seq", "act_embed")
    return x, aux


def _scan_blocks(params, x, cfg: ModelConfig, positions):
    """Scan over stacked layers; heterogeneous patterns scan in groups."""
    aux0 = jnp.zeros((), jnp.float32)
    group = cfg.global_every if (cfg.chunk_size and cfg.global_every) else 1
    n_groups = cfg.num_layers // group
    rem = cfg.num_layers - n_groups * group

    def body(carry, p):
        x, aux = carry
        for j in range(group):
            pj = _layer_params(p, j) if group > 1 else p
            x, aux = _block(pj, x, cfg, positions, j, aux)
        return (x, aux), None

    stacked = jax.tree.map(
        lambda a: a[:n_groups * group].reshape(
            (n_groups, group) + a.shape[1:]) if group > 1
        else a[:n_groups * group],
        params["blocks"])
    (x, aux), _ = jax.lax.scan(body, (x, aux0), stacked)
    for i in range(rem):
        p = _layer_params(params["blocks"], n_groups * group + i)
        x, aux = _block(p, x, cfg, positions, i, aux)
    return x, aux


def _default_positions(cfg: ModelConfig, b: int, s: int):
    pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    if cfg.mrope_sections:
        return pos[None].repeat(3, 0)            # [3, B, S] (text layout)
    return pos


def forward(params, batch: dict, cfg: ModelConfig, *, last_only=False):
    """Training / prefill forward -> (logits [B,S,V], aux_loss).

    ``last_only`` slices the final position BEFORE the unembedding matmul
    (serving prefill needs one next-token distribution, not B x S x V)."""
    if "embeds" in batch:                        # stub modality frontend
        x = shard_act(batch["embeds"].astype(COMPUTE_DTYPE) * cfg.embed_scale,
                      "batch", "seq", "act_embed")
        b, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = embed(params, tokens, cfg)
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, b, s)
    x, aux = _scan_blocks(params, x, cfg, positions)
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"].astype(jnp.float32), cfg.norm_eps)
    return unembed(params, x, cfg), aux


def loss_fn(params, batch: dict, cfg: ModelConfig):
    logits, aux = forward(params, batch, cfg)
    return cross_entropy(logits, batch["labels"]) + aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache_specs(cfg: ModelConfig, batch: int, s_max: int):
    shape, axes = cache_shape(cfg, batch, s_max)
    return {"k": spec(shape, axes, init="zeros", dtype=COMPUTE_DTYPE),
            "v": spec(shape, axes, init="zeros", dtype=COMPUTE_DTYPE)}


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """tokens: [B, 1]; pos: [B] -> (logits [B, V], new cache)."""
    x = embed(params, tokens, cfg)
    group = cfg.global_every if (cfg.chunk_size and cfg.global_every) else 1
    n_groups = cfg.num_layers // group
    rem = cfg.num_layers - n_groups * group

    def body(x, xs):
        p, ck, cv = xs
        cks, cvs = [], []
        for j in range(group):
            pj = _layer_params(p, j) if group > 1 else p
            ckj = ck[j] if group > 1 else ck
            cvj = cv[j] if group > 1 else cv
            mk = layer_mask_kind(cfg, j)
            h = rms_norm(x, pj["ln1"].astype(jnp.float32), cfg.norm_eps)
            h, ckj, cvj = decode_attention(pj["attn"], h, cfg, ckj, cvj,
                                           pos, **mk)
            x = x + h * cfg.residual_scale
            h = rms_norm(x, pj["ln2"].astype(jnp.float32), cfg.norm_eps)
            if cfg.family == "moe":
                h, _ = moe_block(pj["moe"], h, cfg, decode=True)
            else:
                h = swiglu(pj["mlp"], h)
            x = x + h * cfg.residual_scale
            cks.append(ckj)
            cvs.append(cvj)
        ck = jnp.stack(cks) if group > 1 else cks[0]
        cv = jnp.stack(cvs) if group > 1 else cvs[0]
        return x, (ck, cv)

    def regroup(a):
        return (a[:n_groups * group].reshape((n_groups, group) + a.shape[1:])
                if group > 1 else a[:n_groups * group])

    stacked = jax.tree.map(regroup, params["blocks"])
    ck, cv = regroup(cache["k"]), regroup(cache["v"])
    x, (ck, cv) = jax.lax.scan(body, x, (stacked, ck, cv))
    ck = ck.reshape((n_groups * group,) + ck.shape[2:]) if group > 1 else ck
    cv = cv.reshape((n_groups * group,) + cv.shape[2:]) if group > 1 else cv
    if rem:
        tails_k, tails_v = [], []
        for i in range(rem):
            li = n_groups * group + i
            p = _layer_params(params["blocks"], li)
            mk = layer_mask_kind(cfg, i)
            h = rms_norm(x, p["ln1"].astype(jnp.float32), cfg.norm_eps)
            h, cki, cvi = decode_attention(p["attn"], h, cfg, cache["k"][li],
                                           cache["v"][li], pos, **mk)
            x = x + h * cfg.residual_scale
            h = rms_norm(x, p["ln2"].astype(jnp.float32), cfg.norm_eps)
            if cfg.family == "moe":
                h, _ = moe_block(p["moe"], h, cfg, decode=True)
            else:
                h = swiglu(p["mlp"], h)
            x = x + h * cfg.residual_scale
            tails_k.append(cki)
            tails_v.append(cvi)
        ck = jnp.concatenate([ck, jnp.stack(tails_k)], axis=0)
        cv = jnp.concatenate([cv, jnp.stack(tails_v)], axis=0)
    x = rms_norm(x, params["final_norm"].astype(jnp.float32), cfg.norm_eps)
    logits = unembed(params, x, cfg)
    return logits[:, 0], {"k": ck, "v": cv}

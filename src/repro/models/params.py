"""Parameter specification trees.

A model is described by a pytree of :class:`ParamSpec` leaves (shape +
logical axes + initializer).  From one spec tree we derive:

- materialised parameters (`init_params`) for real runs,
- `jax.ShapeDtypeStruct` stand-ins (`abstract_params`) for the dry-run,
- `NamedSharding` trees (`param_shardings`) from the sharding rules.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.runtime.sharding import ShardingRules


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | scaled
    scale: float | None = None    # stddev override
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} do not match shape {self.shape}")


def spec(shape: Sequence[int], axes: Sequence[str | None], init: str = "normal",
         scale: float | None = None, dtype: Any = jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _fan_in(shape: tuple[int, ...]) -> int:
    # stacked-layer params carry a leading "layers" dim; fan-in is dim -2
    return shape[-2] if len(shape) >= 2 else shape[-1]


def init_leaf(key: jax.Array, s: ParamSpec) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    std = s.scale if s.scale is not None else 1.0 / math.sqrt(_fan_in(s.shape))
    return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)


def init_params(specs, key: jax.Array):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef,
                              [init_leaf(k, s) for k, s in zip(keys, leaves)])


def abstract_params(specs):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        specs, is_leaf=_is_spec)


def param_shardings(specs, mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda s: rules.sharding_for(s.axes, s.shape, mesh),
        specs, is_leaf=_is_spec)


def param_specs_pspec(specs, mesh, rules: ShardingRules):
    """PartitionSpec tree (for shard_map in_specs etc.)."""
    return jax.tree.map(
        lambda s: rules.spec_for(s.axes, s.shape, mesh),
        specs, is_leaf=_is_spec)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def tree_bytes(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves)

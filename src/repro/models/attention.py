"""GQA attention for all transformer-family archs: full / sliding-window /
chunked-local(+periodic-global) masks, QKV bias, per-head qk-norm, partial
RoPE and M-RoPE; prefill and single-token decode against a KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.kernels.flash_attention import ops as fa
from repro.kernels.decode_attention import ops as da
from repro.runtime.sharding import (current_flags, current_mesh,
                                    current_rules, gathered, shard_act)
from ._compat import shard_map
from .config import ModelConfig
from .layers import apply_rope, rms_norm
from .params import spec


def attention_specs(cfg: ModelConfig, layers: int):
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    L = (layers,)
    out = {
        "wq": spec(L + (d, q), ("layers", "embed", "heads")),
        "wk": spec(L + (d, kv), ("layers", "embed", "kv_heads")),
        "wv": spec(L + (d, kv), ("layers", "embed", "kv_heads")),
        "wo": spec(L + (q, d), ("layers", "heads", "embed")),
    }
    if cfg.qkv_bias:
        out |= {
            "bq": spec(L + (q,), ("layers", "heads"), init="zeros"),
            "bk": spec(L + (kv,), ("layers", "kv_heads"), init="zeros"),
            "bv": spec(L + (kv,), ("layers", "kv_heads"), init="zeros"),
        }
    if cfg.qk_norm:
        out |= {
            "q_norm": spec(L + (cfg.head_dim,), ("layers", None), init="ones"),
            "k_norm": spec(L + (cfg.head_dim,), ("layers", None), init="ones"),
        }
    return out


def _project_qkv(p, x, cfg: ModelConfig, positions, *, rope: bool):
    b, s, _ = x.shape
    q = x @ gathered(p["wq"], "embed", "heads", dtype=x.dtype)
    k = x @ gathered(p["wk"], "embed", "kv_heads", dtype=x.dtype)
    v = x @ gathered(p["wv"], "embed", "kv_heads", dtype=x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"].astype(jnp.float32), cfg.norm_eps)
        k = rms_norm(k, p["k_norm"].astype(jnp.float32), cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, theta=cfg.rope_theta,
                       rope_pct=cfg.rope_pct,
                       mrope_sections=cfg.mrope_sections)
        k = apply_rope(k, positions, theta=cfg.rope_theta,
                       rope_pct=cfg.rope_pct,
                       mrope_sections=cfg.mrope_sections)
    return q, k, v


def layer_mask_kind(cfg: ModelConfig, layer_idx) -> dict:
    """Per-layer mask parameters (llama4: every `global_every`-th layer is
    global full attention with NoPE; others chunked-local with RoPE)."""
    if cfg.chunk_size and cfg.global_every:
        is_global = (layer_idx + 1) % cfg.global_every == 0
        return dict(window=None,
                    chunk=None if is_global else cfg.chunk_size,
                    rope=not is_global)
    return dict(window=cfg.sliding_window, chunk=cfg.chunk_size, rope=True)


def _headparallel_flash(q, k, v, mesh, batch_axes, **kw):
    """§Perf variant: explicit head-parallel attention.  Each model rank
    runs the flash scan on its own heads with NO collectives inside — the
    alternative (GSPMD inferring layouts for the blocked scan) reconciles
    fwd/remat/bwd layouts with score-sized all-gathers/all-reduces
    (measured 580 GB/device/step on llama4 train)."""
    bspec = (batch_axes if len(batch_axes) > 1
             else (batch_axes[0] if batch_axes else None))

    def body(q, k, v):
        return fa.flash_attention(q, k, v, **kw)

    spec = P(bspec, None, "model", None)
    return shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                         out_specs=spec, check_vma=False)(q, k, v)


def self_attention(p, x, cfg: ModelConfig, positions, *, causal=True,
                   window=None, chunk=None, rope=True):
    """Training / prefill attention.  x: [B, S, D]."""
    q, k, v = _project_qkv(p, x, cfg, positions, rope=rope)
    b, s = x.shape[:2]
    mesh = current_mesh()
    m = mesh.shape.get("model", 1) if mesh is not None else 1
    if (current_flags().get("headparallel_attn") and mesh is not None
            and m > 1 and cfg.num_heads % m == 0
            and cfg.num_kv_heads % m == 0):
        rules = current_rules()
        baxes = tuple(a for a in rules.mesh_axes_for("batch", mesh)
                      if a != "model" and b % mesh.shape[a] == 0)
        out = _headparallel_flash(q, k, v, mesh, baxes, causal=causal,
                                  window=window, chunk=chunk)
    else:
        q = shard_act(q, "batch", "seq", "act_heads", None)
        out = fa.flash_attention(q, k, v, causal=causal, window=window,
                                 chunk=chunk)
    out = out.reshape(b, s, cfg.q_dim)
    return out @ gathered(p["wo"], "heads", "embed", dtype=x.dtype)


def _sharded_flash_decode(q, k, v, cache_k, cache_v, pos, mesh, batch_axes):
    """§Perf variant: explicit flash-decoding over a sequence-sharded
    cache.  shard_map over ('model' x batch axes): each model rank scores
    its local cache slots (partial softmax), the combine is a psum
    log-sum-exp, and the cache update is a LOCAL scatter on the owning
    shard (OOB indices drop elsewhere) — no implicit cache all-gather /
    re-shard, which is exactly what the baseline HLO shows."""
    s_max = cache_k.shape[1]
    m = mesh.shape["model"]
    s_loc = s_max // m
    bspec = (batch_axes if len(batch_axes) > 1
             else (batch_axes[0] if batch_axes else None))

    def body(q, k, v, ck, cv, pos):
        rank = jax.lax.axis_index("model")
        local_slot = pos - rank * s_loc                       # [B]
        own = (local_slot >= 0) & (local_slot < s_loc)
        idx = jnp.where(own, local_slot, s_loc)               # OOB -> drop
        bi = jnp.arange(q.shape[0])
        ck = ck.at[bi, idx].set(k[:, 0].astype(ck.dtype), mode="drop")
        cv = cv.at[bi, idx].set(v[:, 0].astype(cv.dtype), mode="drop")
        slot_pos = rank * s_loc + jnp.arange(s_loc)
        mask = slot_pos[None, :] <= pos[:, None]              # causal+valid
        acc, mx, l = da.partial_decode(q[:, 0], ck, cv, mask)
        out = da.combine_partials(acc, mx, l, "model")
        return out[:, None].astype(q.dtype), ck, cv

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec), P(bspec), P(bspec),
                  P(bspec, "model"), P(bspec, "model"), P(bspec)),
        out_specs=(P(bspec), P(bspec, "model"), P(bspec, "model")),
        check_vma=False,
    )(q, k, v, cache_k, cache_v, pos)


def decode_attention(p, x, cfg: ModelConfig, cache_k, cache_v, pos, *,
                     window=None, chunk=None, rope=True):
    """Single-token decode.  x: [B, 1, D]; cache_[kv]: [B, S_max, KVH, Dh];
    pos: [B] number of tokens already in the cache.  Returns
    (out [B, 1, D], new_cache_k, new_cache_v)."""
    b = x.shape[0]
    positions = (pos[None, :, None].repeat(3, 0) if cfg.mrope_sections
                 else pos[:, None])
    q, k, v = _project_qkv(p, x, cfg, positions, rope=rope)
    s_max = cache_k.shape[1]

    mesh = current_mesh()
    if (current_flags().get("sharded_decode") and mesh is not None
            and "model" in mesh.axis_names and window is None
            and chunk is None and s_max % mesh.shape["model"] == 0):
        rules = current_rules()
        baxes = tuple(a for a in rules.mesh_axes_for("cache_batch", mesh)
                      if b % mesh.shape[a] == 0)
        out, cache_k, cache_v = _sharded_flash_decode(
            q, k, v, cache_k, cache_v, pos, mesh, baxes)
        out = out.reshape(b, 1, cfg.q_dim)
        return out @ p["wo"].astype(x.dtype), cache_k, cache_v
    if window is not None and s_max <= window:
        # rolling cache: position modulo window (long-context decode)
        slot = pos % s_max
    else:
        slot = pos
    idx = slot[:, None]
    cache_k = jax.vmap(
        lambda c, kk, i: jax.lax.dynamic_update_slice(c, kk, (i, 0, 0))
    )(cache_k, k.astype(cache_k.dtype), slot)
    cache_v = jax.vmap(
        lambda c, vv, i: jax.lax.dynamic_update_slice(c, vv, (i, 0, 0))
    )(cache_v, v.astype(cache_v.dtype), slot)
    valid = jnp.minimum(pos + 1, s_max)
    out = da.decode_attention(
        q[:, 0], cache_k, cache_v, valid,
        pos=pos, window=window, chunk=chunk, rolling=window is not None
        and s_max <= window)
    out = out.reshape(b, 1, cfg.q_dim)
    return out @ p["wo"].astype(x.dtype), cache_k, cache_v


def cache_shape(cfg: ModelConfig, batch: int, s_max: int):
    """KV cache ShapeDtypeStruct axes for one layer stack."""
    if cfg.sliding_window is not None:
        s_max = min(s_max, cfg.sliding_window)
    shape = (cfg.num_layers, batch, s_max, cfg.num_kv_heads, cfg.head_dim)
    axes = ("layers", "cache_batch", "cache_seq", None, None)
    return shape, axes

"""Whisper-tiny backbone (arXiv:2212.04356): encoder-decoder transformer.

The conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings [B, encoder_seq, d_model].  The backbone is
real: pre-LN encoder (bidirectional), decoder with causal self-attention +
cross-attention over the encoder output, GELU MLPs with biases, sinusoidal
positions (extended past the published 448 max for synthetic decode
shapes), tied embedding output head.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa
from repro.kernels.decode_attention import ops as da
from repro.runtime.sharding import shard_act
from .config import ModelConfig
from .layers import (COMPUTE_DTYPE, cross_entropy, gelu_mlp, layer_norm,
                     sinusoidal_at, sinusoidal_positions)
from .params import spec


def _attn_specs(cfg: ModelConfig, layers: int, prefix_dim: int):
    d, q = prefix_dim, cfg.q_dim
    L = (layers,)
    return {
        "wq": spec(L + (d, q), ("layers", "embed", "heads")),
        "bq": spec(L + (q,), ("layers", "heads"), init="zeros"),
        "wk": spec(L + (d, q), ("layers", "embed", "heads")),
        "wv": spec(L + (d, q), ("layers", "embed", "heads")),
        "bv": spec(L + (q,), ("layers", "heads"), init="zeros"),
        "wo": spec(L + (q, d), ("layers", "heads", "embed")),
        "bo": spec(L + (d,), ("layers", "embed"), init="zeros"),
    }


def _mlp_specs(cfg: ModelConfig, layers: int, d_ff: int):
    d = cfg.d_model
    L = (layers,)
    return {
        "fc1": spec(L + (d, d_ff), ("layers", "embed", "ffn")),
        "b1": spec(L + (d_ff,), ("layers", "ffn"), init="zeros"),
        "fc2": spec(L + (d_ff, d), ("layers", "ffn", "embed")),
        "b2": spec(L + (d,), ("layers", "embed"), init="zeros"),
    }


def _ln_specs(layers: int, d: int, name: str):
    return {
        f"{name}_w": spec((layers, d), ("layers", "embed"), init="ones"),
        f"{name}_b": spec((layers, d), ("layers", "embed"), init="zeros"),
    }


def whisper_specs(cfg: ModelConfig):
    d = cfg.d_model
    e_l, d_l = cfg.encoder_layers, cfg.num_layers
    e_ff = cfg.encoder_d_ff or cfg.d_ff
    enc = {
        "attn": _attn_specs(cfg, e_l, d),
        "mlp": _mlp_specs(cfg, e_l, e_ff),
        **_ln_specs(e_l, d, "ln1"), **_ln_specs(e_l, d, "ln2"),
    }
    dec = {
        "self_attn": _attn_specs(cfg, d_l, d),
        "cross_attn": _attn_specs(cfg, d_l, d),
        "mlp": _mlp_specs(cfg, d_l, cfg.d_ff),
        **_ln_specs(d_l, d, "ln1"), **_ln_specs(d_l, d, "ln2"),
        **_ln_specs(d_l, d, "ln3"),
    }
    return {
        "embedding": spec((cfg.vocab_size, d), ("vocab", "embed"),
                          scale=0.02),
        "encoder": enc,
        "decoder": dec,
        "enc_norm_w": spec((d,), ("embed",), init="ones"),
        "enc_norm_b": spec((d,), ("embed",), init="zeros"),
        "dec_norm_w": spec((d,), ("embed",), init="ones"),
        "dec_norm_b": spec((d,), ("embed",), init="zeros"),
    }


def _heads(x, cfg):
    b, s, _ = x.shape
    return x.reshape(b, s, cfg.num_heads, cfg.head_dim)


def _attn(p, xq, xkv, cfg, *, causal):
    q = _heads(xq @ p["wq"].astype(xq.dtype) + p["bq"].astype(xq.dtype), cfg)
    k = _heads(xkv @ p["wk"].astype(xq.dtype), cfg)
    v = _heads(xkv @ p["wv"].astype(xq.dtype) + p["bv"].astype(xq.dtype), cfg)
    o = fa.flash_attention(q, k, v, causal=causal)
    b, s = xq.shape[:2]
    return (o.reshape(b, s, cfg.q_dim) @ p["wo"].astype(xq.dtype)
            + p["bo"].astype(xq.dtype))


def encode(params, frames, cfg: ModelConfig):
    """frames: [B, S_enc, D] stub conv-frontend output."""
    x = frames.astype(COMPUTE_DTYPE)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model)[None]
    x = shard_act(x, "batch", "seq", "act_embed")

    def body(x, p):
        h = layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
        x = x + _attn(p["attn"], h, h, cfg, causal=False)
        h = layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
        x = x + gelu_mlp(p["mlp"], h)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layer_norm(x, params["enc_norm_w"], params["enc_norm_b"],
                      cfg.norm_eps)


def _embed_tokens(params, tokens, cfg):
    x = params["embedding"].astype(COMPUTE_DTYPE)[tokens]
    return x + sinusoidal_positions(tokens.shape[1], cfg.d_model)[None]


def decode_prefill(params, tokens, enc_out, cfg: ModelConfig,
                   last_only=False):
    x = shard_act(_embed_tokens(params, tokens, cfg),
                  "batch", "seq", "act_embed")

    def body(x, p):
        h = layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
        x = x + _attn(p["self_attn"], h, h, cfg, causal=True)
        h = layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
        x = x + _attn(p["cross_attn"], h, enc_out, cfg, causal=False)
        h = layer_norm(x, p["ln3_w"], p["ln3_b"], cfg.norm_eps)
        x = x + gelu_mlp(p["mlp"], h)
        return x, None

    x, _ = jax.lax.scan(body, x, params["decoder"])
    if last_only:
        x = x[:, -1:]
    x = layer_norm(x, params["dec_norm_w"], params["dec_norm_b"],
                   cfg.norm_eps)
    return x @ params["embedding"].astype(x.dtype).T


def forward(params, batch: dict, cfg: ModelConfig, *, last_only=False):
    enc_out = encode(params, batch["frames"], cfg)
    logits = decode_prefill(params, batch["tokens"], enc_out, cfg,
                            last_only=last_only)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch: dict, cfg: ModelConfig):
    logits, _ = forward(params, batch, cfg)
    return cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# decode: self-attn KV cache + precomputed cross-attn KV
# ---------------------------------------------------------------------------

def init_cache_specs(cfg: ModelConfig, batch: int, s_max: int):
    L = cfg.num_layers
    kv = (L, batch, s_max, cfg.num_heads, cfg.head_dim)
    enc_kv = (L, batch, cfg.encoder_seq, cfg.num_heads, cfg.head_dim)
    axes = ("layers", "cache_batch", "cache_seq", None, None)
    enc_axes = ("layers", "cache_batch", None, None, None)
    return {
        "k": spec(kv, axes, init="zeros", dtype=COMPUTE_DTYPE),
        "v": spec(kv, axes, init="zeros", dtype=COMPUTE_DTYPE),
        "ek": spec(enc_kv, enc_axes, init="zeros", dtype=COMPUTE_DTYPE),
        "ev": spec(enc_kv, enc_axes, init="zeros", dtype=COMPUTE_DTYPE),
    }


def precompute_cross_kv(params, enc_out, cfg: ModelConfig):
    """Fill the ek/ev cache entries once per request batch."""
    def per_layer(p):
        k = _heads(enc_out @ p["wk"].astype(enc_out.dtype), cfg)
        v = _heads(enc_out @ p["wv"].astype(enc_out.dtype) +
                   p["bv"].astype(enc_out.dtype), cfg)
        return k, v

    ks, vs = jax.vmap(per_layer)(params["decoder"]["cross_attn"])
    return ks.astype(COMPUTE_DTYPE), vs.astype(COMPUTE_DTYPE)


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    b = tokens.shape[0]
    x = params["embedding"].astype(COMPUTE_DTYPE)[tokens]
    x = x + sinusoidal_at(pos, cfg.d_model)[:, None]

    enc_valid = jnp.full((b,), cfg.encoder_seq, jnp.int32)

    def body(x, xs):
        p, ck, cv, ek, ev = xs
        h = layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
        q = _heads(h @ p["self_attn"]["wq"].astype(h.dtype) +
                   p["self_attn"]["bq"].astype(h.dtype), cfg)
        k = _heads(h @ p["self_attn"]["wk"].astype(h.dtype), cfg)
        v = _heads(h @ p["self_attn"]["wv"].astype(h.dtype) +
                   p["self_attn"]["bv"].astype(h.dtype), cfg)
        ck = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice(
            c, kk, (i, 0, 0)))(ck, k.astype(ck.dtype), pos)
        cv = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice(
            c, vv, (i, 0, 0)))(cv, v.astype(cv.dtype), pos)
        o = da.decode_attention(q[:, 0], ck, cv,
                                jnp.minimum(pos + 1, ck.shape[1]))
        o = o.reshape(b, 1, cfg.q_dim)
        x = (x + o @ p["self_attn"]["wo"].astype(x.dtype)
             + p["self_attn"]["bo"].astype(x.dtype))
        h = layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
        q = _heads(h @ p["cross_attn"]["wq"].astype(h.dtype) +
                   p["cross_attn"]["bq"].astype(h.dtype), cfg)
        o = da.decode_attention(q[:, 0], ek, ev, enc_valid)
        o = o.reshape(b, 1, cfg.q_dim)
        x = (x + o @ p["cross_attn"]["wo"].astype(x.dtype)
             + p["cross_attn"]["bo"].astype(x.dtype))
        h = layer_norm(x, p["ln3_w"], p["ln3_b"], cfg.norm_eps)
        x = x + gelu_mlp(p["mlp"], h)
        return x, (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["ek"], cache["ev"]))
    x = layer_norm(x, params["dec_norm_w"], params["dec_norm_b"],
                   cfg.norm_eps)
    logits = x @ params["embedding"].astype(x.dtype).T
    return logits[:, 0], {"k": ck, "v": cv, "ek": cache["ek"],
                          "ev": cache["ev"]}

"""Unified model API: one `Model` facade per architecture family.

Everything downstream (runtime steps, dry-run, examples, payload tasks)
talks to this interface only:

    m = build_model(cfg)
    m.specs()                         -> ParamSpec pytree
    m.loss(params, batch)             -> scalar (train objective)
    m.forward(params, batch)          -> (logits, aux)
    m.cache_specs(batch, s_max)       -> ParamSpec pytree (decode state)
    m.decode_step(params, cache, tokens, pos) -> (logits [B, V], cache)
    m.input_specs(shape)              -> ShapeDtypeStruct batch stand-ins
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import rwkv6 as rwkv_model
from . import ssm as ssm_model
from . import transformer as tf_model
from . import whisper as whisper_model
from .config import ModelConfig
from .layers import COMPUTE_DTYPE


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    _specs: Callable[[ModelConfig], Any]
    _loss: Callable
    _forward: Callable
    _cache_specs: Callable | None
    _decode: Callable | None

    def specs(self):
        return self._specs(self.cfg)

    def loss(self, params, batch):
        return self._loss(params, batch, self.cfg)

    def forward(self, params, batch, **kw):
        return self._forward(params, batch, self.cfg, **kw)

    def cache_specs(self, batch: int, s_max: int):
        if self._cache_specs is None:
            raise ValueError(f"{self.cfg.name} has no decode path")
        return self._cache_specs(self.cfg, batch, s_max)

    def decode_step(self, params, cache, tokens, pos):
        return self._decode(params, cache, tokens, pos, self.cfg)

    # -- batch stand-ins -----------------------------------------------------
    def input_specs(self, *, batch: int, seq: int, mode: str = "train"):
        """ShapeDtypeStruct stand-ins for one step's data inputs.

        mode: train | prefill | decode.  Decode returns (tokens [B,1],
        pos [B]); the cache is supplied separately via cache_specs.
        """
        cfg = self.cfg
        i32 = jnp.int32
        if mode == "decode":
            return {"tokens": jax.ShapeDtypeStruct((batch, 1), i32),
                    "pos": jax.ShapeDtypeStruct((batch,), i32)}
        out: dict[str, Any] = {}
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq, cfg.d_model), COMPUTE_DTYPE)
            out["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
        elif cfg.family == "vlm":
            out["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
            out["positions"] = jax.ShapeDtypeStruct((3, batch, seq), i32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
        if mode == "train":
            out["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
        return out

    def make_batch(self, key, *, batch: int, seq: int, mode: str = "train"):
        """Concrete synthetic batch matching input_specs (smoke tests)."""
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        if mode == "decode":
            return {
                "tokens": jax.random.randint(ks[0], (batch, 1), 0,
                                             cfg.vocab_size),
                "pos": jnp.zeros((batch,), jnp.int32),
            }
        out: dict[str, Any] = {}
        if cfg.family == "encdec":
            out["frames"] = jax.random.normal(
                ks[2], (batch, cfg.encoder_seq, cfg.d_model), jnp.float32
            ).astype(COMPUTE_DTYPE)
        if cfg.family == "vlm":
            pos = jnp.arange(seq, dtype=jnp.int32)[None, :].repeat(batch, 0)
            out["positions"] = pos[None].repeat(3, 0)
        out["tokens"] = jax.random.randint(ks[0], (batch, seq), 0,
                                           cfg.vocab_size)
        if mode == "train":
            out["labels"] = jax.random.randint(ks[1], (batch, seq), 0,
                                               cfg.vocab_size)
        return out


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return Model(cfg, tf_model.transformer_specs, tf_model.loss_fn,
                     tf_model.forward, tf_model.init_cache_specs,
                     tf_model.decode_step)
    if cfg.family == "ssm" and cfg.rwkv:
        return Model(cfg, rwkv_model.rwkv6_specs, rwkv_model.loss_fn,
                     rwkv_model.forward, rwkv_model.init_cache_specs,
                     rwkv_model.decode_step)
    if cfg.family in ("ssm", "hybrid"):
        return Model(cfg, ssm_model.zamba2_specs, ssm_model.loss_fn,
                     ssm_model.forward, ssm_model.init_cache_specs,
                     ssm_model.decode_step)
    if cfg.family == "encdec":
        return Model(cfg, whisper_model.whisper_specs, whisper_model.loss_fn,
                     whisper_model.forward, whisper_model.init_cache_specs,
                     whisper_model.decode_step)
    raise ValueError(f"unknown family {cfg.family!r}")

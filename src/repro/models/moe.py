"""Mixture-of-Experts layer with expert parallelism.

Three dispatch strategies sharing the same capacity-based scatter/combine
helpers:

- ``moe_ep_a2a``   (train / prefill): shard_map over the full mesh; tokens
  are sharded over ('pod','data') x batch and 'model' x sequence, experts
  over 'model'.  Local top-k routing -> capacity-bounded send buffer
  [M, E_loc, C, D] -> ``lax.all_to_all`` over 'model' -> grouped expert
  matmul -> reverse all_to_all -> weighted combine.  The only cross-device
  traffic is the routed tokens (2 x k x capacity), not full activations.

- ``moe_ep_psum`` (decode, S == 1): tokens replicated over 'model'; each
  model rank routes identically, processes only assignments that target
  its local experts, and the combine is a psum.  No all_to_all on the
  latency-critical decode path; traffic is 2 x activation bytes.

- ``moe_local``   (no mesh / smoke tests): the same scatter-dispatch on a
  single device, no collectives.

Routing is classic top-k with optional renormalised weights (qwen3) and a
load-balance auxiliary loss (Shazeer-style f*P); overflowed tokens beyond
the capacity factor are dropped (counted into the aux metrics).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime.sharding import current_flags, current_mesh, current_rules
from ._compat import shard_map
from .config import ModelConfig
from .params import spec


def moe_specs(cfg: ModelConfig, layers: int):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    L = (layers,)
    out = {
        "router": spec(L + (d, e), ("layers", "embed", None), scale=0.02),
        "w_gate": spec(L + (e, d, f), ("layers", "experts", "embed",
                                       "expert_ffn")),
        "w_up": spec(L + (e, d, f), ("layers", "experts", "embed",
                                     "expert_ffn")),
        "w_down": spec(L + (e, f, d), ("layers", "experts", "expert_ffn",
                                       "embed")),
    }
    if cfg.shared_expert:
        out |= {
            "s_gate": spec(L + (d, cfg.d_ff), ("layers", "embed", "ffn")),
            "s_up": spec(L + (d, cfg.d_ff), ("layers", "embed", "ffn")),
            "s_down": spec(L + (cfg.d_ff, d), ("layers", "ffn", "embed")),
        }
    return out


@dataclasses.dataclass(frozen=True)
class MoEOptions:
    capacity_factor: float = 1.25
    aux_weight: float = 0.01


# ---------------------------------------------------------------------------
# routing + scatter helpers (shared by all strategies)
# ---------------------------------------------------------------------------

def _route(router_w, x, cfg: ModelConfig):
    """x: [T, D] -> (weights [T, k], experts [T, k], aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    w, e = jax.lax.top_k(probs, cfg.experts_per_token)         # [T, k]
    if cfg.norm_topk:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balance aux: E * sum_e mean(onehot_e) * mean(prob_e)
    ids = jax.nn.one_hot(e[:, 0], cfg.num_experts, dtype=jnp.float32)
    aux = cfg.num_experts * jnp.mean(
        ids.mean(0) * probs.mean(0)) * cfg.num_experts
    return w, e, aux


def _positions_in_expert(flat_e, num_experts: int):
    """Rank of each assignment within its expert (stable arrival order)."""
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                       # [A, E]
    return jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]


def _dispatch(x, flat_e, pos, capacity: int, num_experts: int):
    """Scatter tokens into [E, C, D]; overflow (pos >= C) is dropped."""
    keep = pos < capacity
    e_idx = jnp.where(keep, flat_e, num_experts)               # OOB -> drop
    buf = jnp.zeros((num_experts, capacity) + x.shape[1:], x.dtype)
    return buf.at[e_idx, jnp.minimum(pos, capacity - 1)].set(
        x, mode="drop"), keep


def _collect(buf, flat_e, pos, capacity, keep):
    """Gather per-assignment outputs back out of [E, C, D]."""
    out = buf[jnp.minimum(flat_e, buf.shape[0] - 1),
              jnp.minimum(pos, capacity - 1)]
    return jnp.where(keep[:, None], out, 0.0)


def _expert_ffn(p, buf):
    """buf: [E, C, D] with per-expert weight stacks [E, D, F]/[E, F, D]."""
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                p["w_gate"].astype(buf.dtype)))
         * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype)))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(buf.dtype))


def _capacity(tokens: int, num_experts: int, k: int, factor: float) -> int:
    c = math.ceil(tokens * k / num_experts * factor)
    return max(8, -(-c // 8) * 8)                              # pad to 8


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

def _moe_tokens(p, xt, cfg: ModelConfig, opts: MoEOptions):
    """Single-device dispatch on a flat token batch xt: [T, D]."""
    t, dd = xt.shape
    w, e, aux = _route(p["router"], xt, cfg)
    k = cfg.experts_per_token
    cap = _capacity(t, cfg.num_experts, k, opts.capacity_factor)
    flat_e = e.reshape(t * k)
    pos = _positions_in_expert(flat_e, cfg.num_experts)
    x_rep = jnp.repeat(xt, k, axis=0)                          # [T*k, D]
    buf, keep = _dispatch(x_rep, flat_e, pos, cap, cfg.num_experts)
    out_buf = _expert_ffn(p, buf)
    y = _collect(out_buf, flat_e, pos, cap, keep)              # [T*k, D]
    y = (y.reshape(t, k, dd) * w[..., None].astype(y.dtype)).sum(axis=1)
    return y, aux


def moe_local(p, x, cfg: ModelConfig, opts: MoEOptions = MoEOptions()):
    b, s, dd = x.shape
    y, aux = _moe_tokens(p, x.reshape(b * s, dd), cfg, opts)
    return y.reshape(b, s, dd), aux


def _dev_groups(mesh):
    """(model-axis size, experts per model rank)."""
    return mesh.shape["model"]


def moe_ep_a2a(p, x, cfg: ModelConfig, opts: MoEOptions = MoEOptions()):
    """Training/prefill EP: shard_map with all_to_all dispatch.

    x: [B, S, D] sharded P(('pod','data'), 'model', None) inside.
    Expert stacks sharded on the expert dim over 'model'.
    """
    mesh = current_mesh()
    m = mesh.shape["model"]
    e_loc = cfg.num_experts // m
    k = cfg.experts_per_token
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(router_w, w_gate, w_up, w_down, xs):
        bl, sl, dd = xs.shape
        t = bl * sl
        xt = xs.reshape(t, dd)
        w, e, aux = _route(router_w, xt, cfg)
        aux = jax.lax.pmean(aux, ("model",) + batch_axes)
        cap = _capacity(t, cfg.num_experts, k, opts.capacity_factor)
        flat_e = e.reshape(t * k)
        # rank within expert (global expert id -> also rank within
        # (dest device, local expert) since e determines both)
        pos = _positions_in_expert(flat_e, cfg.num_experts)
        x_rep = jnp.repeat(xt, k, axis=0)
        buf, keep = _dispatch(x_rep, flat_e, pos, cap, cfg.num_experts)
        # [E, C, D] -> [M, E_loc, C, D] -> exchange over 'model'
        sb = buf.reshape(m, e_loc, cap, dd)
        rb = jax.lax.all_to_all(sb, "model", split_axis=0, concat_axis=0,
                                tiled=False)
        # rb: [M_src, E_loc, C, D] -> experts see M*C tokens each
        rb = rb.transpose(1, 0, 2, 3).reshape(e_loc, m * cap, dd)
        pl = {"w_gate": w_gate, "w_up": w_up, "w_down": w_down}
        ob = _expert_ffn(pl, rb)
        ob = ob.reshape(e_loc, m, cap, dd).transpose(1, 0, 2, 3)
        cb = jax.lax.all_to_all(ob, "model", split_axis=0, concat_axis=0,
                                tiled=False)
        y = _collect(cb.reshape(cfg.num_experts, cap, dd), flat_e, pos,
                     cap, keep)
        y = (y.reshape(t, k, dd) * w[..., None].astype(y.dtype)).sum(axis=1)
        return y.reshape(bl, sl, dd), aux

    rules = current_rules()
    baxes = tuple(a for a in rules.mesh_axes_for("batch", mesh)
                  if x.shape[0] % mesh.shape[a] == 0)
    # tokens are additionally split over 'model' along sequence unless the
    # batch dim already covers the model axis (full-DP variants)
    seq_entry = "model" if "model" not in baxes else None
    xspec = P(baxes if len(baxes) > 1 else (baxes[0] if baxes else None),
              seq_entry, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P("model"), P("model"), P("model"), xspec),
        out_specs=(xspec, P()),
        check_vma=False,
    )(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)


def moe_ep_psum(p, x, cfg: ModelConfig, opts: MoEOptions = MoEOptions()):
    """Decode EP: tokens replicated over 'model'; each rank computes its
    local experts' share and the combine is a psum over 'model'."""
    mesh = current_mesh()
    m = mesh.shape["model"]
    e_loc = cfg.num_experts // m
    k = cfg.experts_per_token
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(router_w, w_gate, w_up, w_down, xs):
        bl, sl, dd = xs.shape
        t = bl * sl
        xt = xs.reshape(t, dd)
        w, e, aux = _route(router_w, xt, cfg)
        aux = jax.lax.pmean(aux, ("model",) + batch_axes)
        my = jax.lax.axis_index("model")
        local = (e // e_loc) == my                              # [T, k]
        le = jnp.where(local, e % e_loc, e_loc)                 # OOB -> drop
        cap = _capacity(t, e_loc, k, opts.capacity_factor * m)
        flat_e = le.reshape(t * k)
        pos = _positions_in_expert(flat_e, e_loc + 1)
        x_rep = jnp.repeat(xt, k, axis=0)
        buf, keep = _dispatch(x_rep, flat_e, pos, cap, e_loc)
        pl = {"w_gate": w_gate, "w_up": w_up, "w_down": w_down}
        ob = _expert_ffn(pl, buf)
        y = _collect(ob, flat_e, pos, cap, keep & (flat_e < e_loc))
        y = (y.reshape(t, k, dd) * w[..., None].astype(y.dtype)).sum(axis=1)
        y = jax.lax.psum(y, "model")
        return y.reshape(bl, sl, dd), aux

    rules = current_rules()
    xspec = P(rules.mesh_axes_for("batch", mesh) or None, None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P("model"), P("model"), P("model"), xspec),
        out_specs=(xspec, P()),
        check_vma=False,
    )(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)


def moe_block(p, x, cfg: ModelConfig, *, decode: bool = False,
              opts: MoEOptions = MoEOptions()):
    """Dispatching MoE entry point; adds the shared expert if configured.

    Returns (y [B,S,D], aux_loss scalar).

    Perf flag ``moe_gather_bf16`` (§Perf hillclimb): expert weight stacks
    are cast to bf16 BEFORE the shard_map boundary, so the ZeRO-style
    all-gather over the 'data' axis moves half the bytes (fp32 master
    copies stay in the optimizer; the cast is differentiable and the
    backward reduce-scatter is bf16 too).
    """
    mesh = current_mesh()
    s = x.shape[1]
    if current_flags().get("moe_gather_bf16"):
        p = dict(p)
        for k in ("w_gate", "w_up", "w_down"):
            p[k] = p[k].astype(jnp.bfloat16)
    use_ep = (mesh is not None and "model" in mesh.axis_names
              and mesh.shape["model"] > 1
              and cfg.num_experts % mesh.shape["model"] == 0)
    if not use_ep:
        y, aux = moe_local(p, x, cfg, opts)
    elif decode or s % mesh.shape["model"] != 0:
        y, aux = moe_ep_psum(p, x, cfg, opts)
    else:
        y, aux = moe_ep_a2a(p, x, cfg, opts)
    if cfg.shared_expert:
        from repro.runtime.sharding import gathered
        h = (jax.nn.silu(x @ gathered(p["s_gate"], "embed", "ffn",
                                      dtype=x.dtype))
             * (x @ gathered(p["s_up"], "embed", "ffn", dtype=x.dtype)))
        y = y + h @ gathered(p["s_down"], "ffn", "embed", dtype=x.dtype)
    return y, aux * opts.aux_weight

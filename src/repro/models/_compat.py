"""Version compatibility shims for the model stack (no pallas imports).

jax moved ``shard_map`` from ``jax.experimental.shard_map`` (0.4.x) to a
top-level ``jax.shard_map`` and renamed the replication-check kwarg
``check_rep`` -> ``check_vma`` along the way.  Call sites use the new
spelling; this shim maps it onto whichever API the installed jax has.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})

"""RWKV6 "Finch" (arXiv:2404.05892): attention-free token/channel mixing
with data-dependent decay.  Uses the chunked linear-attention kernel for
train/prefill and the O(1) state update for decode.

Faithful structure: data-dependent token-shift interpolation (ddlerp) with
a shared low-rank projection for the five mix targets (w/k/v/r/g), a
low-rank data-dependent decay ``w_t = exp(-exp(w0 + tanh(x W_a) W_b))``,
per-channel bonus ``u``, per-head GroupNorm, and squared-ReLU channel mix.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6 import ops as rwkv_ops
from repro.runtime.sharding import shard_act
from .config import ModelConfig
from .layers import (COMPUTE_DTYPE, cross_entropy, embed, embed_specs,
                     rms_norm, unembed)
from .params import spec

HEAD_K = 64          # rwkv6 head size
DDLERP_RANK = 32     # token-shift lora rank
DECAY_RANK = 64      # decay lora rank
MIX_TARGETS = 5      # w, k, v, r, g


def rwkv6_specs(cfg: ModelConfig):
    d, f, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    Ld = (L, d)
    blocks = {
        "ln1": spec(Ld, ("layers", "embed"), init="ones"),
        "ln2": spec(Ld, ("layers", "embed"), init="ones"),
        # time mix
        "mu_x": spec(Ld, ("layers", "embed"), init="zeros"),
        "mu_wkvrg": spec((L, MIX_TARGETS, d), ("layers", None, "embed"),
                         init="zeros"),
        "ts_a": spec((L, d, MIX_TARGETS * DDLERP_RANK),
                     ("layers", "embed", None), scale=0.02),
        "ts_b": spec((L, MIX_TARGETS, DDLERP_RANK, d),
                     ("layers", None, None, "embed"), scale=0.02),
        "w_r": spec((L, d, d), ("layers", "embed", "heads")),
        "w_k": spec((L, d, d), ("layers", "embed", "heads")),
        "w_v": spec((L, d, d), ("layers", "embed", "heads")),
        "w_g": spec((L, d, d), ("layers", "embed", "heads")),
        "w_o": spec((L, d, d), ("layers", "heads", "embed")),
        "decay_base": spec(Ld, ("layers", "embed"), init="zeros"),
        "decay_a": spec((L, d, DECAY_RANK), ("layers", "embed", None),
                        scale=0.02),
        "decay_b": spec((L, DECAY_RANK, d), ("layers", None, "embed"),
                        scale=0.02),
        "bonus_u": spec(Ld, ("layers", "embed"), init="zeros"),
        "gn_w": spec(Ld, ("layers", "embed"), init="ones"),
        "gn_b": spec(Ld, ("layers", "embed"), init="zeros"),
        # channel mix
        "cm_mu_k": spec(Ld, ("layers", "embed"), init="zeros"),
        "cm_mu_r": spec(Ld, ("layers", "embed"), init="zeros"),
        "cm_k": spec((L, d, f), ("layers", "embed", "ffn")),
        "cm_v": spec((L, f, d), ("layers", "ffn", "embed")),
        "cm_r": spec((L, d, d), ("layers", "embed", "heads")),
    }
    return {
        **embed_specs(cfg),
        "blocks": blocks,
        "final_norm": spec((d,), ("embed",), init="ones"),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / supplied state for t = 0)."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return prev.at[:, :1].set(first.astype(x.dtype))


def _ddlerp(p, x, xx):
    """Data-dependent lerp producing the 5 mixed inputs [B,S,5,D]."""
    mixed = x + (xx - x) * p["mu_x"].astype(x.dtype)
    lo = jnp.tanh(mixed @ p["ts_a"].astype(x.dtype))
    b, s, _ = x.shape
    lo = lo.reshape(b, s, MIX_TARGETS, DDLERP_RANK)
    delta = jnp.einsum("bstr,trd->bstd", lo, p["ts_b"].astype(x.dtype))
    mu = p["mu_wkvrg"].astype(x.dtype)[None, None] + delta
    return x[:, :, None] + (xx - x)[:, :, None] * mu


def _decay(p, xw):
    """Data-dependent per-channel decay in (0, 1)."""
    lo = (jnp.tanh(xw @ p["decay_a"].astype(xw.dtype))
          @ p["decay_b"].astype(xw.dtype))
    logit = p["decay_base"].astype(jnp.float32) + lo.astype(jnp.float32)
    return jnp.exp(-jnp.exp(jnp.clip(logit, -10.0, 4.0)))


def _group_norm(x, w, b, h, eps=1e-5):
    """Per-head LayerNorm over K channels.  x: [B, S, D]."""
    bs, s, d = x.shape
    xg = x.reshape(bs, s, h, d // h).astype(jnp.float32)
    mu = xg.mean(-1, keepdims=True)
    var = ((xg - mu) ** 2).mean(-1, keepdims=True)
    xg = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(bs, s, d)
    return (xg * w + b).astype(x.dtype)


def _time_mix(p, x, cfg: ModelConfig, *, shift_state=None, state=None):
    b, s, d = x.shape
    h = d // HEAD_K
    xx = _shift(x, shift_state)
    mixed = _ddlerp(p, x, xx)
    xw, xk, xv, xr, xg = (mixed[:, :, i] for i in range(MIX_TARGETS))
    r = xr @ p["w_r"].astype(x.dtype)
    k = xk @ p["w_k"].astype(x.dtype)
    v = xv @ p["w_v"].astype(x.dtype)
    g = jax.nn.silu(xg @ p["w_g"].astype(x.dtype))
    w = _decay(p, xw)
    u = p["bonus_u"].astype(jnp.float32).reshape(h, HEAD_K)

    def heads(t):
        return t.reshape(b, s, h, HEAD_K)

    if state is None:
        o = rwkv_ops.rwkv6(heads(r), heads(k), heads(v), heads(w), u)
        new_state = None
    else:
        o, new_state = rwkv_ops.rwkv6_decode_step(
            state, heads(r)[:, 0], heads(k)[:, 0], heads(v)[:, 0],
            heads(w)[:, 0], u)
        o = o[:, None]
    o = o.reshape(b, s, d)
    o = _group_norm(o, p["gn_w"].astype(jnp.float32),
                    p["gn_b"].astype(jnp.float32), h)
    out = (o * g) @ p["w_o"].astype(x.dtype)
    return out, x[:, -1], new_state


def _channel_mix(p, x, *, shift_state=None):
    xx = _shift(x, shift_state)
    xk = x + (xx - x) * p["cm_mu_k"].astype(x.dtype)
    xr = x + (xx - x) * p["cm_mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"].astype(x.dtype)))
    k = shard_act(k, "batch", None, "act_ffn")
    return (jax.nn.sigmoid(xr @ p["cm_r"].astype(x.dtype))
            * (k @ p["cm_v"].astype(x.dtype)), x[:, -1])


def _block(p, x, cfg: ModelConfig):
    h, _, _ = _time_mix(p, rms_norm(x, p["ln1"].astype(jnp.float32),
                                    cfg.norm_eps), cfg)
    x = x + h
    h, _ = _channel_mix(p, rms_norm(x, p["ln2"].astype(jnp.float32),
                                    cfg.norm_eps))
    x = x + h
    return shard_act(x, "batch", "seq", "act_embed")


def forward(params, batch: dict, cfg: ModelConfig, *, last_only=False):
    x = embed(params, batch["tokens"], cfg)

    def body(x, p):
        return _block(p, x, cfg), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"].astype(jnp.float32), cfg.norm_eps)
    return unembed(params, x, cfg), jnp.zeros((), jnp.float32)


def loss_fn(params, batch: dict, cfg: ModelConfig):
    logits, _ = forward(params, batch, cfg)
    return cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# decode: O(1) state per layer
# ---------------------------------------------------------------------------

def init_cache_specs(cfg: ModelConfig, batch: int, s_max: int):
    d, L = cfg.d_model, cfg.num_layers
    h = d // HEAD_K
    return {
        "wkv": spec((L, batch, h, HEAD_K, HEAD_K),
                    ("layers", "cache_batch", None, None, None),
                    init="zeros", dtype=jnp.float32),
        "shift_tm": spec((L, batch, d), ("layers", "cache_batch", "embed"),
                         init="zeros", dtype=COMPUTE_DTYPE),
        "shift_cm": spec((L, batch, d), ("layers", "cache_batch", "embed"),
                         init="zeros", dtype=COMPUTE_DTYPE),
    }


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    x = embed(params, tokens, cfg)

    def body(x, xs):
        p, st_wkv, st_tm, st_cm = xs
        xn = rms_norm(x, p["ln1"].astype(jnp.float32), cfg.norm_eps)
        h, new_tm, new_wkv = _time_mix(p, xn, cfg, shift_state=st_tm,
                                       state=st_wkv)
        x = x + h
        xn = rms_norm(x, p["ln2"].astype(jnp.float32), cfg.norm_eps)
        h, new_cm = _channel_mix(p, xn, shift_state=st_cm)
        x = x + h
        return x, (new_wkv.astype(st_wkv.dtype), new_tm.astype(st_tm.dtype),
                   new_cm.astype(st_cm.dtype))

    x, (wkv, tm, cm) = jax.lax.scan(
        body, x, (params["blocks"], cache["wkv"], cache["shift_tm"],
                  cache["shift_cm"]))
    x = rms_norm(x, params["final_norm"].astype(jnp.float32), cfg.norm_eps)
    logits = unembed(params, x, cfg)
    return logits[:, 0], {"wkv": wkv, "shift_tm": tm, "shift_cm": cm}

"""Mamba2 blocks and the Zamba2 hybrid (arXiv:2411.15242): a Mamba2
backbone with a single *shared-weight* transformer block invoked every
``shared_attn_every`` layers, plus per-invocation LoRA deltas (rank 128)
on the shared block's input projections.

The Mamba2 block follows arXiv:2405.21060: fused in-projection to
(z, xBC, dt), depthwise causal conv over xBC, SSD chunked scan (Pallas
kernel / chunked jnp), gated RMSNorm, out-projection.  The shared
attention block consumes concat([hidden, original_embedding]) (2*d_model)
as in Zamba2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm import ops as ssd_ops
from repro.runtime.sharding import shard_act
from .config import ModelConfig
from .layers import (COMPUTE_DTYPE, cross_entropy, embed, embed_specs,
                     rms_norm, unembed)
from .params import spec
from .transformer import _layer_params

HEAD_P = 64          # mamba2 head dim
LORA_RANK = 128
SHARED_WINDOW = 4096  # KV window kept for the shared attn at long context


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // HEAD_P
    return d_in, n_heads


def mamba_specs(cfg: ModelConfig, layers: int):
    d = cfg.d_model
    d_in, nh = _dims(cfg)
    n = cfg.ssm_state
    conv_dim = d_in + 2 * n
    L = (layers,)
    return {
        "ln": spec(L + (d,), ("layers", "embed"), init="ones"),
        "w_in": spec(L + (d, 2 * d_in + 2 * n + nh),
                     ("layers", "embed", "heads")),
        "conv_w": spec(L + (cfg.ssm_conv, conv_dim), ("layers", None, None),
                       scale=0.5),
        "conv_b": spec(L + (conv_dim,), ("layers", None), init="zeros"),
        "dt_bias": spec(L + (nh,), ("layers", None), init="zeros"),
        "a_log": spec(L + (nh,), ("layers", None), init="zeros"),
        "d_skip": spec(L + (nh,), ("layers", None), init="ones"),
        "gn": spec(L + (d_in,), ("layers", None), init="ones"),
        "w_out": spec(L + (d_in, d), ("layers", "heads", "embed")),
    }


def shared_block_specs(cfg: ModelConfig, n_inv: int):
    """One shared transformer block over concat inputs + per-invocation
    LoRA on the qkv and gate/up projections."""
    d, dd = cfg.d_model, 2 * cfg.d_model
    q, kv, f = cfg.q_dim, cfg.kv_dim, cfg.d_ff
    N = (n_inv,)
    return {
        "ln1": spec((dd,), ("embed",), init="ones"),
        "wq": spec((dd, q), ("embed", "heads")),
        "wk": spec((dd, kv), ("embed", "kv_heads")),
        "wv": spec((dd, kv), ("embed", "kv_heads")),
        "wo": spec((q, d), ("heads", "embed")),
        "ln2": spec((d,), ("embed",), init="ones"),
        "gate": spec((d, f), ("embed", "ffn")),
        "up": spec((d, f), ("embed", "ffn")),
        "down": spec((f, d), ("ffn", "embed")),
        # per-invocation LoRA deltas
        "lq_a": spec(N + (dd, LORA_RANK), ("layers", "embed", None), scale=0.02),
        "lq_b": spec(N + (LORA_RANK, q), ("layers", None, "heads"), scale=0.02),
        "lk_a": spec(N + (dd, LORA_RANK), ("layers", "embed", None), scale=0.02),
        "lk_b": spec(N + (LORA_RANK, kv), ("layers", None, None), scale=0.02),
        "lg_a": spec(N + (d, LORA_RANK), ("layers", "embed", None), scale=0.02),
        "lg_b": spec(N + (LORA_RANK, f), ("layers", None, "ffn"), scale=0.02),
    }


def n_shared_invocations(cfg: ModelConfig) -> int:
    if not cfg.shared_attn_every:
        return 0
    return cfg.num_layers // cfg.shared_attn_every


def zamba2_specs(cfg: ModelConfig):
    out = {
        **embed_specs(cfg),
        "blocks": mamba_specs(cfg, cfg.num_layers),
        "final_norm": spec((cfg.d_model,), ("embed",), init="ones"),
    }
    n_inv = n_shared_invocations(cfg)
    if n_inv:
        out["shared"] = shared_block_specs(cfg, n_inv)
    return out


# ---------------------------------------------------------------------------
# mamba2 block
# ---------------------------------------------------------------------------

def _conv1d(x, w, b, *, state=None):
    """Depthwise causal conv.  x: [B, S, C]; w: [W, C].  state: [B, W-1, C]
    holds the trailing inputs for decode; returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : width - 1])
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(width))
    new_state = xp[:, x.shape[1]:]
    return y + b.astype(x.dtype), new_state


def mamba_block(p, x, cfg: ModelConfig, *, conv_state=None, ssm_state=None):
    """Returns (out, new_conv_state, new_ssm_state)."""
    b, s, d = x.shape
    d_in, nh = _dims(cfg)
    n = cfg.ssm_state
    h = x @ p["w_in"].astype(x.dtype)
    z, xbc, dt = jnp.split(h, [d_in, 2 * d_in + 2 * n], axis=-1)
    xbc, new_conv = _conv1d(xbc, p["conv_w"], p["conv_b"], state=conv_state)
    xbc = jax.nn.silu(xbc)
    xs, bb, cc = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    xh = xs.reshape(b, s, nh, HEAD_P)
    if ssm_state is None:
        y = ssd_ops.ssd(xh, dt, p["a_log"].astype(jnp.float32), bb, cc,
                        p["d_skip"].astype(jnp.float32))
        new_ssm = None
    else:
        y, new_ssm = ssd_ops.ssd_decode_step(
            ssm_state, xh[:, 0], dt[:, 0], p["a_log"].astype(jnp.float32),
            bb[:, 0], cc[:, 0], p["d_skip"].astype(jnp.float32))
        y = y[:, None]
    y = y.reshape(b, s, d_in)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = ((yf * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x.dtype)
         * p["gn"].astype(x.dtype))
    return y @ p["w_out"].astype(x.dtype), new_conv, new_ssm


# ---------------------------------------------------------------------------
# shared attention block (zamba2)
# ---------------------------------------------------------------------------

def _lora(x, a, b):
    return (x @ a.astype(x.dtype)) @ b.astype(x.dtype)


def shared_block(p, x, x0, cfg: ModelConfig, inv: int, positions, *,
                 cache=None, pos=None):
    """x: hidden [B,S,D]; x0: original embeddings.  inv is static.
    cache: (k, v) windowed KV for decode; returns (out, new_cache)."""
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.decode_attention import ops as da

    b, s, d = x.shape
    cat = jnp.concatenate([x, x0], axis=-1)
    h = rms_norm(cat, p["ln1"].astype(jnp.float32), cfg.norm_eps)
    q = h @ p["wq"].astype(h.dtype) + _lora(h, p["lq_a"][inv], p["lq_b"][inv])
    k = h @ p["wk"].astype(h.dtype) + _lora(h, p["lk_a"][inv], p["lk_b"][inv])
    v = h @ p["wv"].astype(h.dtype)
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    new_cache = None
    if cache is None:
        o = fa.flash_attention(q, k, v, causal=True)
        o = o.reshape(b, s, cfg.q_dim)
    else:
        ck, cv = cache
        s_max = ck.shape[1]
        slot = (jnp.minimum(pos, s_max - 1) if s_max >= SHARED_WINDOW
                else pos % s_max)
        rolling = s_max <= SHARED_WINDOW
        slot = pos % s_max if rolling else pos
        ck = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice(
            c, kk, (i, 0, 0)))(ck, k.astype(ck.dtype), slot)
        cv = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice(
            c, vv, (i, 0, 0)))(cv, v.astype(cv.dtype), slot)
        valid = jnp.minimum(pos + 1, s_max)
        o = da.decode_attention(q[:, 0], ck, cv, valid, pos=pos,
                                window=SHARED_WINDOW if rolling else None,
                                rolling=rolling)
        o = o.reshape(b, 1, cfg.q_dim)
        new_cache = (ck, cv)
    x = x + o @ p["wo"].astype(x.dtype)
    h = rms_norm(x, p["ln2"].astype(jnp.float32), cfg.norm_eps)
    g = jax.nn.silu(h @ p["gate"].astype(h.dtype) +
                    _lora(h, p["lg_a"][inv], p["lg_b"][inv]))
    h = g * (h @ p["up"].astype(h.dtype))
    h = shard_act(h, "batch", None, "act_ffn")
    x = x + h @ p["down"].astype(h.dtype)
    return x, new_cache


# ---------------------------------------------------------------------------
# full zamba2 model
# ---------------------------------------------------------------------------

def forward(params, batch: dict, cfg: ModelConfig, *, last_only=False):
    x = embed(params, batch["tokens"], cfg)
    x0 = x
    b, s = x.shape[:2]
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    every = cfg.shared_attn_every or (cfg.num_layers + 1)
    n_inv = n_shared_invocations(cfg)
    n_grouped = n_inv * every
    rem = cfg.num_layers - n_grouped

    if n_inv:
        # python loop over invocation groups (shared block differs per inv
        # only through LoRA indices, which must be static)
        for g in range(n_inv):
            grp = jax.tree.map(
                lambda a: a[g * every:(g + 1) * every], params["blocks"])

            def body(x, p):
                y, _, _ = mamba_block(p, rms_norm(
                    x, p["ln"].astype(jnp.float32), cfg.norm_eps), cfg)
                return shard_act(x + y, "batch", "seq", "act_embed"), None

            x, _ = jax.lax.scan(body, x, grp)
            x, _ = shared_block(params["shared"], x, x0, cfg, g, positions)
            x = shard_act(x, "batch", "seq", "act_embed")
    for i in range(rem):
        p = _layer_params(params["blocks"], n_grouped + i)
        y, _, _ = mamba_block(p, rms_norm(
            x, p["ln"].astype(jnp.float32), cfg.norm_eps), cfg)
        x = x + y
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"].astype(jnp.float32), cfg.norm_eps)
    return unembed(params, x, cfg), jnp.zeros((), jnp.float32)


def loss_fn(params, batch: dict, cfg: ModelConfig):
    logits, _ = forward(params, batch, cfg)
    return cross_entropy(logits, batch["labels"])


def init_cache_specs(cfg: ModelConfig, batch: int, s_max: int):
    d_in, nh = _dims(cfg)
    n = cfg.ssm_state
    conv_dim = d_in + 2 * n
    L = cfg.num_layers
    out = {
        "ssm": spec((L, batch, nh, n, HEAD_P),
                    ("layers", "cache_batch", None, None, None),
                    init="zeros", dtype=jnp.float32),
        "conv": spec((L, batch, cfg.ssm_conv - 1, conv_dim),
                     ("layers", "cache_batch", None, None),
                     init="zeros", dtype=COMPUTE_DTYPE),
    }
    n_inv = n_shared_invocations(cfg)
    if n_inv:
        w = min(s_max, SHARED_WINDOW)
        out["shared_k"] = spec(
            (n_inv, batch, w, cfg.num_kv_heads, cfg.head_dim),
            ("layers", "cache_batch", "cache_seq", None, None),
            init="zeros", dtype=COMPUTE_DTYPE)
        out["shared_v"] = spec(
            (n_inv, batch, w, cfg.num_kv_heads, cfg.head_dim),
            ("layers", "cache_batch", "cache_seq", None, None),
            init="zeros", dtype=COMPUTE_DTYPE)
    return out


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    x = embed(params, tokens, cfg)
    x0 = x
    every = cfg.shared_attn_every or (cfg.num_layers + 1)
    n_inv = n_shared_invocations(cfg)
    n_grouped = n_inv * every
    rem = cfg.num_layers - n_grouped

    def mamba_step(x, p, cs, ss):
        xn = rms_norm(x, p["ln"].astype(jnp.float32), cfg.norm_eps)
        y, new_cs, new_ss = mamba_block(p, xn, cfg, conv_state=cs,
                                        ssm_state=ss)
        return x + y, new_cs.astype(cs.dtype), new_ss.astype(ss.dtype)

    new_ssm, new_conv = [], []
    sk, sv = [], []
    for g in range(n_inv):
        grp = jax.tree.map(lambda a: a[g * every:(g + 1) * every],
                           params["blocks"])
        cs_g = cache["conv"][g * every:(g + 1) * every]
        ss_g = cache["ssm"][g * every:(g + 1) * every]

        def body(x, xs):
            p, cs, ss = xs
            x, ncs, nss = mamba_step(x, p, cs, ss)
            return x, (ncs, nss)

        x, (ncs, nss) = jax.lax.scan(body, x, (grp, cs_g, ss_g))
        new_conv.append(ncs)
        new_ssm.append(nss)
        x, (k_g, v_g) = shared_block(
            params["shared"], x, x0, cfg, g, None,
            cache=(cache["shared_k"][g], cache["shared_v"][g]), pos=pos)
        sk.append(k_g)
        sv.append(v_g)
    for i in range(rem):
        li = n_grouped + i
        p = _layer_params(params["blocks"], li)
        x, ncs, nss = mamba_step(x, p, cache["conv"][li], cache["ssm"][li])
        new_conv.append(ncs[None])
        new_ssm.append(nss[None])
    new_cache = {
        "conv": jnp.concatenate(new_conv, axis=0),
        "ssm": jnp.concatenate(new_ssm, axis=0),
    }
    if n_inv:
        new_cache["shared_k"] = jnp.stack(sk)
        new_cache["shared_v"] = jnp.stack(sv)
    x = rms_norm(x, params["final_norm"].astype(jnp.float32), cfg.norm_eps)
    logits = unembed(params, x, cfg)
    return logits[:, 0], new_cache

"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "ssm", "hybrid", "encdec", "moe", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // num_heads

    # attention variants
    qkv_bias: bool = False
    qk_norm: bool = False                # per-head RMSNorm on q/k (qwen3, stablelm)
    sliding_window: int | None = None    # SWA (h2o-danube)
    chunk_size: int | None = None        # chunked-local attention (llama4)
    global_every: int = 0                # every k-th layer full/NoPE (llama4)
    rope_theta: float = 1_000_000.0
    rope_pct: float = 1.0                # partial rotary (stablelm: 0.25)
    mrope_sections: tuple[int, ...] = () # M-RoPE (qwen2-vl): t/h/w splits

    # residual / embedding scaling (minicpm muP-style)
    residual_scale: float = 1.0
    embed_scale: float = 1.0
    logit_scale: float = 1.0
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    shared_expert: bool = False          # llama4 shared expert
    norm_topk: bool = False              # qwen3 normalises top-k weights

    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    shared_attn_every: int = 0           # zamba2: shared attn block cadence
    rwkv: bool = False

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0                 # fixed frame count (whisper: 1500)
    encoder_d_ff: int = 0

    # frontends provided as stubs (audio frames / vision patches)
    frontend_stub: bool = False

    norm_eps: float = 1e-5
    max_position: int = 1 << 20

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived -----------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded memory?"""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True                  # SSM state + windowed shared attn
        return self.sliding_window is not None

    @property
    def is_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive path
                     # (whisper via its decoder; encoder KV is precomputed)

    def param_count_estimate(self) -> int:
        """Rough parameter count (embeddings + blocks), for 6ND math."""
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.rwkv:
            blk = L * (4 * d * d + 2 * d * self.d_ff + 3 * d * 64)
            return emb + blk
        attn = d * self.q_dim * 2 + d * self.kv_dim * 2
        if self.family == "moe":
            ff = self.num_experts * 3 * d * self.moe_d_ff
            if self.shared_expert:
                ff += 3 * d * self.d_ff
        else:
            ff = 3 * d * self.d_ff
        if self.family == "ssm" or self.family == "hybrid":
            d_in = self.ssm_expand * d
            blk = (2 * d * d_in + d_in * d  # in/out proj
                   + d_in * self.ssm_state * 2 + d_in * self.ssm_conv)
            ssm_layers = L
            out = emb + ssm_layers * blk
            if self.shared_attn_every:
                out += attn + 3 * d * self.d_ff
            if self.family == "hybrid":
                return out
            return out
        total = emb + L * (attn + ff)
        if self.encoder_layers:
            total += self.encoder_layers * (attn + 3 * d * self.encoder_d_ff
                                            if self.encoder_d_ff else attn + ff)
        return total

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count_estimate()
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * self.q_dim * 2 + d * self.kv_dim * 2
        ff = self.experts_per_token * 3 * d * self.moe_d_ff
        if self.shared_expert:
            ff += 3 * d * self.d_ff
        router = d * self.num_experts
        return emb + L * (attn + ff + router)

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized config of the same family."""
        small = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads
            else 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            max_position=4096,
        )
        if self.num_kv_heads == self.num_heads:
            small["num_kv_heads"] = 4
        if self.num_experts:
            small.update(num_experts=8, experts_per_token=min(
                2, self.experts_per_token), moe_d_ff=64)
        if self.ssm_state:
            small.update(ssm_state=16)
        if self.encoder_layers:
            small.update(encoder_layers=2, encoder_seq=64, encoder_d_ff=256)
        if self.mrope_sections:
            small.update(mrope_sections=(4, 6, 6))
        if self.sliding_window:
            small.update(sliding_window=64)
        if self.chunk_size:
            small.update(chunk_size=64)
        if self.shared_attn_every:
            small.update(shared_attn_every=2, num_layers=4)
        small.update(overrides)
        return dataclasses.replace(self, **small)

"""AdamW in pure JAX over arbitrary parameter pytrees.

fp32 master weights + moments; global-norm clipping; decoupled weight
decay.  No optax dependency (offline container) — ~60 lines is all the
optimizer needs to be.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    """Returns (new_params, new_state).  ``lr`` may be a scalar array."""
    step = state.step + 1
    b1t = 1.0 - b1 ** step.astype(jnp.float32)
    b2t = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mhat = m / b1t
        vhat = v / b2t
        pf = p.astype(jnp.float32)
        # decay only matrices (ndim >= 2), the common LLM convention
        wd = weight_decay if p.ndim >= 2 else 0.0
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * pf)
        return pf.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, mu=mu, nu=nu)

"""Error-feedback top-k gradient compression (distributed-optimisation
trick for bandwidth-bound cross-pod replication).

Each step transmits only the top ``ratio`` fraction of gradient entries
(by magnitude, per-tensor); the residual is accumulated locally and added
back the next step (error feedback, Karimireddy et al. 2019), which keeps
convergence close to dense SGD/Adam.

In-graph usage: compress BEFORE the cross-pod all-reduce — the dense
intra-pod reduction stays exact, only the slow inter-pod link sees the
sparsified tensor.  Here we expose the pure compression transform; the
runtime wires it into the pod-axis reduction.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressState:
    residual: Any


def compress_init(params) -> CompressState:
    return CompressState(residual=jax.tree.map(
        lambda p: jnp.zeros_like(p, jnp.float32), params))


def _topk_mask(g, ratio: float):
    k = max(1, int(g.size * ratio))
    flat = jnp.abs(g.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def topk_compress_update(grads, state: CompressState, *, ratio: float = 0.1):
    """Returns (sparse_grads, new_state).  sparse + residual == grads +
    old residual (lossless bookkeeping)."""
    def per_tensor(g, r):
        gf = g.astype(jnp.float32) + r
        mask = _topk_mask(gf, ratio)
        sparse = gf * mask
        return sparse.astype(g.dtype), gf - sparse

    out = jax.tree.map(per_tensor, grads, state.residual)
    sparse = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return sparse, CompressState(residual=resid)

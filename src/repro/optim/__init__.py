from .adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm
from .schedules import cosine_schedule, wsd_schedule, make_schedule
from .compress import topk_compress_update, compress_init, CompressState
from .accumulate import GradAccumulator

__all__ = [s for s in dir() if not s.startswith("_")]

"""Gradient accumulation with collective deferral.

``jax.lax.scan`` over microbatches inside one jit'd step: per-microbatch
gradients are summed locally; any data-parallel all-reduce happens ONCE on
the accumulated tensor (XLA hoists the psum out of the scan because the
reduction is linear), so ICI traffic is independent of the microbatch
count."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GradAccumulator:
    num_microbatches: int

    def split(self, batch):
        """[B, ...] -> [n, B/n, ...] for every leaf."""
        n = self.num_microbatches

        def re(x):
            return x.reshape((n, x.shape[0] // n) + x.shape[1:])

        return jax.tree.map(re, batch)

    def grads(self, loss_fn, params, batch):
        """Mean loss and mean grads over microbatches (scanned)."""
        n = self.num_microbatches
        if n <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        micro = self.split(batch)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
            return (loss_acc + loss, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, g), _ = jax.lax.scan(body, (jnp.zeros(()), g0), micro)
        return loss / n, jax.tree.map(lambda x: x / n, g)

"""Learning-rate schedules: cosine (default) and Warmup-Stable-Decay
(WSD, the minicpm-2b schedule, arXiv:2404.06395 §4)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 *
                     (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)


def wsd_schedule(step, *, peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, floor: float = 0.01):
    """Warmup -> Stable (constant) -> exponential Decay over the last
    ``decay_frac`` of training."""
    s = step.astype(jnp.float32)
    decay_start = total * (1.0 - decay_frac)
    warm = peak_lr * s / max(warmup, 1)
    stable = jnp.full_like(s, peak_lr)
    prog = jnp.clip((s - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
    decay = peak_lr * (floor ** prog)
    out = jnp.where(s < warmup, warm, stable)
    return jnp.where(s > decay_start, decay, out)


def make_schedule(kind: str, **kw):
    if kind == "cosine":
        return lambda step: cosine_schedule(step, **kw)
    if kind == "wsd":
        return lambda step: wsd_schedule(step, **kw)
    raise ValueError(f"unknown schedule {kind!r}")

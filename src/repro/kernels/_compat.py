"""Version compatibility shims shared by the Pallas TPU kernels."""

from jax.experimental.pallas import tpu as _pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both.
CompilerParams = (getattr(_pltpu, "CompilerParams", None)
                  or _pltpu.TPUCompilerParams)

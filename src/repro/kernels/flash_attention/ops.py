"""Dispatching wrapper for flash attention.

Three interchangeable implementations with identical semantics:

- ``pallas``      the TPU kernel (kernel.py); interpret=True on CPU tests;
- ``jnp_chunked`` a lax.scan over KV blocks with running softmax — O(S x B)
                  memory, used for dry-run lowering so the compiled HLO has
                  flash-like memory behaviour (no S^2 intermediate);
- ``ref``         the O(S^2) oracle (ref.py).

``flash_attention`` picks per backend: pallas on TPU, jnp_chunked
elsewhere.  All take q [B, Sq, H, D], k/v [B, Skv, KVH, D].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


from . import ref
from .kernel import flash_attention_pallas

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("causal", "window", "chunk",
                                             "q_offset", "block_k"))
def flash_attention_jnp(q, k, v, *, causal=True, window=None, chunk=None,
                        q_offset=0, block_k=512):
    """Streaming softmax over KV blocks in pure jnp (flash semantics).

    NB (§Perf H3, refuted): pinning the blocked tensors / scan carry to
    batch-only shardings here makes traffic WORSE (3x) — GSPMD's chosen
    layouts beat hand pins; the productive fix for small models is
    dropping TP entirely (see §Perf H4), not fighting layout assignment."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    scale = d ** -0.5
    block_k = min(block_k, skv)
    nk = -(-skv // block_k)
    pad = nk * block_k - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # [b,h,sq,d]
    kb = k.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        b, kvh, nk, block_k, d).transpose(2, 0, 1, 3, 4)        # [nk,b,kvh,bk,d]
    vb = v.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        b, kvh, nk, block_k, d).transpose(2, 0, 1, 3, 4)

    q_pos = jnp.arange(sq) + q_offset

    def step(carry, xs):
        m, l, acc, ki = carry[0], carry[1], carry[2], carry[3]
        kblk, vblk = xs
        kblk = jnp.repeat(kblk, group, axis=1)                  # [b,h,bk,d]
        vblk = jnp.repeat(vblk, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kblk)
        k_pos = ki * block_k + jnp.arange(block_k)
        mask = k_pos[None, :] < skv
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        if chunk is not None:
            mask &= (k_pos[None, :] // chunk) == (q_pos[:, None] // chunk)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vblk)
        return (m_new, l_new, acc_new, ki + 1), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)),
                                     (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, chunk=None,
                    q_offset=0, impl="auto", interpret=None):
    """Dispatch: pallas on TPU, jnp_chunked otherwise (incl. dry-run)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      chunk=chunk, q_offset=q_offset,
                                      interpret=interpret)
    if impl == "jnp":
        return flash_attention_jnp(q, k, v, causal=causal, window=window,
                                   chunk=chunk, q_offset=q_offset)
    if impl == "ref":
        return ref.mha_reference(q, k, v, causal=causal, window=window,
                                 chunk=chunk, q_offset=q_offset)
    raise ValueError(f"unknown impl {impl!r}")

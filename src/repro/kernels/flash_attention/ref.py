"""Pure-jnp oracle for flash attention (GQA + causal + sliding-window +
chunked-local masks).  O(S^2) memory — correctness reference only."""

from __future__ import annotations

import jax.numpy as jnp


def attention_mask(q_len: int, kv_len: int, *, causal: bool = True,
                   window: int | None = None, chunk: int | None = None,
                   q_offset: int = 0) -> jnp.ndarray:
    """[q_len, kv_len] boolean mask; True = attend.

    ``q_offset`` is the absolute position of q[0] (decode/prefill-continue).
    ``window``: attend only to the last `window` positions (inclusive of
    self).  ``chunk``: block-diagonal local attention (llama4-style): query
    attends only within its own chunk of size `chunk` (still causal).
    """
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    if chunk is not None:
        mask &= (k_pos // chunk) == (q_pos // chunk)
    return mask


def mha_reference(q, k, v, *, causal=True, window=None, chunk=None,
                  q_offset=0, scale=None, kv_valid_len=None):
    """q: [B, Sq, H, D]; k/v: [B, Skv, KVH, D] with H % KVH == 0.

    Returns [B, Sq, H, D] in q's dtype; softmax in fp32.
    """
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, group, axis=2)
    vf = jnp.repeat(vf, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    mask = attention_mask(sq, skv, causal=causal, window=window, chunk=chunk,
                          q_offset=q_offset)
    if kv_valid_len is not None:
        mask = mask & (jnp.arange(skv)[None, :] < kv_valid_len)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = jnp.where(jnp.isfinite(logits), p, 0.0)
    denom = p.sum(axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)

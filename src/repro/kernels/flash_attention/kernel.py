"""Pallas TPU flash-attention kernel (forward).

TPU-native adaptation: a 3-D grid (batch*heads, q_blocks, kv_blocks) in
which the kv axis is the innermost ("arbitrary") dimension.  Each (bh, qi)
program streams KV tiles HBM->VMEM through BlockSpec pipelining and keeps
the running (max, denominator, accumulator) in VMEM scratch, so the VMEM
working set is ``block_q x d + 2 x block_k x d + block_q x (d + 2)`` —
sized well under v5e VMEM with MXU-aligned (multiple-of-128) matmul dims.

Masks: causal, sliding-window, chunked-local (block-diagonal, llama4).
Fully-masked KV tiles are skipped with `pl.when` (the 2x causal FLOP
saving).

Validated on CPU in interpret mode against `ref.mha_reference`
(tests/test_kernel_flash_attention.py); the compiled path targets TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale, block_q, block_k, seq_q, seq_kv, causal, window,
               chunk, q_offset):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    num_kv = pl.num_programs(2)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset
    k_lo = ki * block_k
    k_hi = k_lo + block_k - 1

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile-level early-out: skip tiles fully outside the mask
    live = (k_lo < seq_kv)
    if causal:
        live &= k_lo <= q_pos[-1]
    if window is not None:
        live &= k_hi > q_pos[0] - window
    if chunk is not None:
        live &= (((k_lo // chunk) <= (q_pos[-1] // chunk))
                 & ((k_hi // chunk) >= (q_pos[0] // chunk)))

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale       # [bq, d]
        k = k_ref[...].astype(jnp.float32)               # [bk, d]
        v = v_ref[...].astype(jnp.float32)
        s = q @ k.T                                      # [bq, bk] on the MXU
        k_pos = k_lo + jax.lax.iota(jnp.int32, block_k)
        mask = (k_pos[None, :] < seq_kv) & (q_pos[:, None] < seq_q + q_offset)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        if chunk is not None:
            mask &= (k_pos[None, :] // chunk) == (q_pos[:, None] // chunk)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1)[:, None])  # [bq, 1]
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                  # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "chunk", "q_offset", "block_q",
                     "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None, chunk=None,
                           q_offset=0, block_q=128, block_k=128,
                           interpret=False):
    """q: [B, Sq, H, D]; k/v: [B, Skv, KVH, D].  GQA by index-map folding:
    q-head ``bh`` reads kv row ``bh // (H // KVH)``."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    scale = d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)

    grid = (b * h, pl.cdiv(sq, block_q), pl.cdiv(skv, block_k))

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=sq, seq_kv=skv, causal=causal, window=window, chunk=chunk,
        q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_k, d),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((None, block_k, d),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            # m, l, acc live in VMEM across kv grid steps
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)

"""Dispatching wrapper for decode attention + the distributed
flash-decoding combine.

- ``decode_attention``: per-device decode (pallas on TPU, jnp elsewhere).
  Under GSPMD with the KV cache sequence-sharded, the jnp einsum path
  compiles to a distributed softmax (all-reduce of max / sum) — the
  flash-decoding pattern — without gathering the cache.
- ``partial_decode`` + ``combine_partials``: explicit shard_map variant
  (psum log-sum-exp) used by the serving runtime when the cache is
  sequence-sharded along the `model` axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .kernel import decode_attention_pallas

NEG_INF = -1e30


def decode_attention_jnp(q, cache_k, cache_v, valid, *, pos=None,
                         window=None, chunk=None, rolling=False):
    return ref.decode_reference(q, cache_k, cache_v, valid, pos=pos,
                                window=window, chunk=chunk, rolling=rolling)


def decode_attention(q, cache_k, cache_v, valid, *, pos=None, window=None,
                     chunk=None, rolling=False, impl="auto", interpret=None):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return decode_attention_pallas(
            q, cache_k, cache_v, valid, pos=pos, window=window, chunk=chunk,
            rolling=rolling, interpret=interpret)
    return decode_attention_jnp(q, cache_k, cache_v, valid, pos=pos,
                                window=window, chunk=chunk, rolling=rolling)


# ---------------------------------------------------------------------------
# Explicit flash-decoding partials (for shard_map serving)
# ---------------------------------------------------------------------------

def partial_decode(q, k_shard, v_shard, shard_mask):
    """Unnormalised attention over one sequence shard.

    q: [B, H, D]; k/v_shard: [B, S_loc, KVH, D]; shard_mask: [B, S_loc]
    True for live slots.  Returns (acc [B,H,D], m [B,H], l [B,H]).
    """
    b, h, d = q.shape
    kvh = k_shard.shape[2]
    group = h // kvh
    qf = q.astype(jnp.float32) * (d ** -0.5)
    kf = jnp.repeat(k_shard.astype(jnp.float32), group, axis=2)
    vf = jnp.repeat(v_shard.astype(jnp.float32), group, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", qf, kf)
    s = jnp.where(shard_mask[:, None, :], s, NEG_INF)
    m = s.max(axis=-1)                                    # [B, H]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(shard_mask[:, None, :], p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhs,bshd->bhd", p, vf)
    return acc, m, l


def combine_partials(acc, m, l, axis_name: str):
    """psum log-sum-exp combine across sequence shards (flash-decoding)."""
    m_glob = jax.lax.pmax(m, axis_name)                   # [B, H]
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis_name)
    acc_glob = jax.lax.psum(acc * corr[..., None], axis_name)
    return acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]

"""Pure-jnp oracle for single-token decode attention against a KV cache."""

from __future__ import annotations

import jax.numpy as jnp


def decode_reference(q, cache_k, cache_v, valid, *, pos=None, window=None,
                     chunk=None, rolling=False, scale=None):
    """q: [B, H, D]; cache_k/v: [B, S, KVH, D]; valid: [B] (# live slots);
    pos: [B] absolute position of the current token (needed for window /
    chunk masks on non-rolling caches).  Returns [B, H, D].
    """
    b, h, d = q.shape
    _, s, kvh, _ = cache_k.shape
    group = h // kvh
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(cache_k.astype(jnp.float32), group, axis=2)
    vf = jnp.repeat(cache_v.astype(jnp.float32), group, axis=2)
    logits = jnp.einsum("bhd,bshd->bhs", qf, kf)
    k_pos = jnp.arange(s)[None, :]                       # [1, S]
    mask = k_pos < valid[:, None]
    if not rolling and pos is not None:
        if window is not None:
            mask &= k_pos > (pos[:, None] - window)
        if chunk is not None:
            mask &= (k_pos // chunk) == (pos[:, None] // chunk)
    logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(jnp.isfinite(logits), p, 0.0)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhs,bshd->bhd", p, vf).astype(q.dtype)

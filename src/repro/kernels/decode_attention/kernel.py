"""Pallas TPU flash-decoding kernel.

Grid: (batch x kv_heads, kv_blocks).  Each program owns one kv head's query
group ([group, D], padded to the 8-sublane MXU minimum), streams KV cache
tiles HBM->VMEM, and keeps running (m, l, acc) in VMEM scratch.  The
per-batch valid length arrives via a scalar-prefetch operand in SMEM so
fully-dead tiles are skipped (`pl.when`), which makes short-context decode
on a long cache cheap.

The distributed variant (KV cache sequence-sharded across the `model` mesh
axis with a psum log-sum-exp combine) lives in ops.sharded_decode — this
kernel is the per-shard workhorse.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(valid_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale, block_k, kvh,
                   window, chunk, rolling):
    bh = pl.program_id(0)
    ki = pl.program_id(1)
    num_kv = pl.num_programs(1)
    b = bh // kvh
    valid = valid_ref[b]
    pos = pos_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_lo = ki * block_k
    live = k_lo < valid
    if not rolling:
        if window is not None:
            live &= (k_lo + block_k - 1) > pos - window
        if chunk is not None:
            live &= (k_lo // chunk) <= (pos // chunk)
            live &= ((k_lo + block_k - 1) // chunk) >= (pos // chunk)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale        # [G, D]
        k = k_ref[...].astype(jnp.float32)                # [block_k, D]
        v = v_ref[...].astype(jnp.float32)
        s = q @ k.T                                       # [G, block_k]
        k_pos = k_lo + jax.lax.iota(jnp.int32, block_k)
        mask = k_pos < valid
        if not rolling:
            if window is not None:
                mask &= k_pos > pos - window
            if chunk is not None:
                mask &= (k_pos // chunk) == (pos // chunk)
        s = jnp.where(mask[None, :], s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1)[:, None])
        p = jnp.exp(s - m_new)
        p = jnp.where(mask[None, :], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "chunk", "rolling",
                                             "block_k", "interpret"))
def decode_attention_pallas(q, cache_k, cache_v, valid, *, pos=None,
                            window=None, chunk=None, rolling=False,
                            block_k=256, interpret=False):
    """q: [B, H, D]; cache_k/v: [B, S, KVH, D]; valid/pos: [B] int32."""
    b, h, d = q.shape
    _, s, kvh, _ = cache_k.shape
    group = h // kvh
    scale = d ** -0.5
    block_k = min(block_k, s)
    if pos is None:
        pos = valid - 1

    # [B*KVH, G, D] query groups; pad G to the 8-sublane minimum
    qg = q.reshape(b, kvh, group, d).reshape(b * kvh, group, d)
    gpad = max(8, group)
    if gpad != group:
        qg = jnp.pad(qg, ((0, 0), (0, gpad - group), (0, 0)))
    kf = cache_k.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
    vf = cache_v.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)

    grid = (b * kvh, pl.cdiv(s, block_k))
    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, kvh=kvh,
        window=window, chunk=chunk, rolling=rolling)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # valid
            pl.BlockSpec(memory_space=pltpu.SMEM),   # pos
            pl.BlockSpec((None, gpad, d), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, gpad, d), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, gpad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((gpad, 1), jnp.float32),
            pltpu.VMEM((gpad, 1), jnp.float32),
            pltpu.VMEM((gpad, d), jnp.float32),
        ],
        interpret=interpret,
    )(valid.astype(jnp.int32), pos.astype(jnp.int32), qg, kf, vf)
    return out[:, :group].reshape(b, kvh, group, d).reshape(b, h, d)

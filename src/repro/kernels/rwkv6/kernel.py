"""Pallas TPU kernel for the RWKV6 chunked linear-attention scan.

TPU-native adaptation of the Finch recurrence: instead of a per-token
recurrent loop (latency-bound on the VPU), the sequence is processed in
chunks of ``block_t`` tokens.  Per (batch x head, chunk) program:

- the chunk state ``S`` [K, V] lives in VMEM scratch and is carried across
  the sequential chunk grid axis;
- within-chunk cumulative log-decays are produced with a lower-triangular
  ones matmul (MXU) instead of ``cumsum`` (unsupported scan on TPU);
- the intra-chunk attention uses the *explicit* decay tensor
  ``exp(Lprev[t] - L[s])`` [C, C, K]: every exponent is <= 0 for s <= t-1,
  so the computation is overflow-safe for arbitrarily strong decays (the
  factorised form ``e^{+a} e^{-b}`` is not);
- the value contraction ``scores @ V`` and the state update run on the MXU.

VMEM working set: 4 x [C, K] inputs + [C, C, K] decay + [K, V] state
= (4*128 + 128*128 + 64) * 64 * 4B ~ 4.5 MB at C=128, K=64 — well inside
v5e's 16 MB in fp32.

Validated on CPU in interpret mode against ``ref.rwkv6_reference``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams as _CompilerParams

LOG_W_MIN = -60.0  # clamp: decays below e^-60 are numerically zero anyway


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                  block_t: int, seq_len: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[...].astype(jnp.float32)            # [C, K]
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)            # [1, K]

    logw = jnp.clip(jnp.log(jnp.maximum(w, 1e-38)), LOG_W_MIN, 0.0)

    # inclusive cumulative log-decay L[t] = sum_{s<=t} log w_s via MXU matmul
    c = block_t
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    tril_inc = (col <= row).astype(jnp.float32)   # [C, C]
    lw = tril_inc @ logw                          # [C, K] inclusive
    lw_prev = lw - logw                           # exclusive (L[t-1]; 0 at t=0)

    # ---- intra-chunk: scores[t, s] = sum_i r[t,i] k[s,i] e^{Lprev[t,i]-L[s,i]}
    # explicit decay tensor; exponents <= 0 for the surviving (s <= t-1) terms
    decay3 = jnp.exp(
        jnp.minimum(lw_prev[:, None, :] - lw[None, :, :], 0.0))  # [C, C, K]
    strict = (col >= row)[..., None]              # keep only s <= t-1
    prod = (r[:, None, :] * k[None, :, :]) * decay3
    scores = jnp.where(strict, 0.0, prod).sum(axis=-1)           # [C, C]
    # diagonal bonus term u
    bonus = (r * u * k).sum(axis=-1)              # [C]
    scores = scores + jnp.where(col == row, bonus[:, None], 0.0)

    s0 = s_ref[...]                               # [K, V]
    o_intra = scores @ v                          # MXU [C,C]@[C,V]
    o_inter = (r * jnp.exp(lw_prev)) @ s0         # MXU [C,K]@[K,V]
    o_ref[...] = (o_intra + o_inter).astype(o_ref.dtype)

    # ---- state update: S' = diag(e^{L[end]}) S0 + (k ⊙ e^{L[end]-L})^T V
    l_end = lw[c - 1]                             # [K]
    k_dec = k * jnp.exp(jnp.minimum(l_end[None, :] - lw, 0.0))
    s_ref[...] = jnp.exp(l_end)[:, None] * s0 + k_dec.T @ v


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_pallas(r, k, v, w, u, *, block_t: int = 128, interpret: bool = False):
    """r/k/v/w: [B, T, H, K]; u: [H, K] -> o: [B, T, H, K].

    T must be a multiple of ``block_t`` (callers pad).  The chunk grid axis
    is sequential ("arbitrary"), carrying the state in VMEM scratch.
    """
    b, t, h, kk = r.shape
    block_t = min(block_t, t)
    assert t % block_t == 0, (t, block_t)

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, kk)

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w)
    grid = (b * h, t // block_t)

    kernel = functools.partial(_rwkv6_kernel, block_t=block_t, seq_len=t)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_t, kk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, block_t, kk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, block_t, kk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, block_t, kk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, kk), lambda bh, ci, h=h: (bh % h, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_t, kk), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, kk), r.dtype),
        scratch_shapes=[pltpu.VMEM((kk, kk), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, u)
    return out.reshape(b, h, t, kk).transpose(0, 2, 1, 3)

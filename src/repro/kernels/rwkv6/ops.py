"""Dispatching wrapper for the RWKV6 chunked scan.

- ``pallas``  the TPU kernel (kernel.py); interpret=True on CPU;
- ``jnp``     chunk-parallel jnp implementation (same math as the kernel,
              vmapped over chunks) — used for dry-run lowering so the HLO
              is chunk-structured rather than a T-step scan;
- ``ref``     the exact per-token recurrence (ref.py).

Also provides ``rwkv6_decode_step`` — the O(1) single-token state update
used by the serving path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import rwkv6_pallas, LOG_W_MIN


@functools.partial(jax.jit, static_argnames=("block_t",))
def rwkv6_jnp(r, k, v, w, u, *, block_t: int = 128):
    """Chunked linear attention in pure jnp (flash semantics, fp32 core)."""
    b, t, h, kk = r.shape
    block_t = min(block_t, t)
    assert t % block_t == 0, (t, block_t)
    nc = t // block_t

    def chunked(x):
        return (x.astype(jnp.float32).transpose(0, 2, 1, 3)
                .reshape(b * h, nc, block_t, kk))

    rc, kc, vc, wc = chunked(r), chunked(k), chunked(v), chunked(w)
    uf = jnp.broadcast_to(u.astype(jnp.float32)[None], (b, h, kk)
                          ).reshape(b * h, kk)

    logw = jnp.clip(jnp.log(jnp.maximum(wc, 1e-38)), LOG_W_MIN, 0.0)
    lw = jnp.cumsum(logw, axis=2)                  # [BH, NC, C, K] inclusive
    lw_prev = lw - logw

    c = block_t
    tpos = jnp.arange(c)
    strict = tpos[None, :] >= tpos[:, None]        # keep only s <= t-1

    # intra-chunk (vectorised over chunks)
    decay3 = jnp.exp(jnp.minimum(
        lw_prev[:, :, :, None, :] - lw[:, :, None, :, :], 0.0))
    prod = rc[:, :, :, None, :] * kc[:, :, None, :, :] * decay3
    scores = jnp.where(strict[None, None, :, :, None], 0.0, prod).sum(-1)
    bonus = jnp.einsum("gctk,gk->gct", rc * kc, uf)
    scores = scores + bonus[..., None] * jnp.eye(c, dtype=jnp.float32)
    o_intra = jnp.einsum("gcts,gcsk->gctk", scores, vc)

    # inter-chunk: scan the state across chunks
    l_end = lw[:, :, -1, :]                        # [BH, NC, K]
    k_dec = kc * jnp.exp(jnp.minimum(l_end[:, :, None, :] - lw, 0.0))
    chunk_kv = jnp.einsum("gctk,gctv->gckv", k_dec, vc)  # [BH, NC, K, V]
    a_chunk = jnp.exp(l_end)                       # [BH, NC, K]

    def step(s, xs):
        a, ckv = xs                                # [BH,K], [BH,K,V]
        out_s = s
        s = a[..., None] * s + ckv
        return s, out_s

    s0 = jnp.zeros((b * h, kk, kk), jnp.float32)
    _, s_in = jax.lax.scan(
        step, s0, (a_chunk.transpose(1, 0, 2), chunk_kv.transpose(1, 0, 2, 3)))
    s_in = s_in.transpose(1, 0, 2, 3)              # state entering each chunk
    o_inter = jnp.einsum("gctk,gckv->gctv", rc * jnp.exp(lw_prev), s_in)

    o = (o_intra + o_inter).reshape(b, h, t, kk).transpose(0, 2, 1, 3)
    return o.astype(r.dtype)


def rwkv6(r, k, v, w, u, *, impl: str = "auto", block_t: int = 128,
          interpret: bool | None = None):
    """Dispatch: pallas on TPU, chunked jnp otherwise (incl. dry-run)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return rwkv6_pallas(r, k, v, w, u, block_t=block_t,
                            interpret=interpret)
    if impl == "jnp":
        return rwkv6_jnp(r, k, v, w, u, block_t=block_t)
    if impl == "ref":
        return ref.rwkv6_reference(r, k, v, w, u)[0]
    raise ValueError(f"unknown impl {impl!r}")


def rwkv6_decode_step(state, r, k, v, w, u):
    """O(1) single-token update.  state: [B, H, K, V]; r/k/v/w: [B, H, K];
    u: [H, K].  Returns (o [B, H, V], new_state)."""
    sf = state.astype(jnp.float32)
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    kv = kf[..., :, None] * vf[..., None, :]
    o = jnp.einsum("bhi,bhij->bhj", rf,
                   sf + u.astype(jnp.float32)[None, :, :, None] * kv)
    new = wf[..., :, None] * sf + kv
    return o.astype(r.dtype), new.astype(state.dtype)

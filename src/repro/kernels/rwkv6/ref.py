"""Pure-jnp oracle for the RWKV6 (Finch) time-mix recurrence.

Exact per-token recurrence in fp32 (arXiv:2404.05892, Eq. 19-22):

    o_t[j] = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]

with a *data-dependent* per-channel decay ``w_t in (0, 1)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_reference(r, k, v, w, u, initial_state=None):
    """r/k/v/w: [B, T, H, K]; u: [H, K].

    Returns (o [B, T, H, K], final_state [B, H, K, K]).
    """
    b, t, h, kk = r.shape
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    s0 = (jnp.zeros((b, h, kk, kk), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(state, xs):
        rt, kt, vt, wt = xs                       # each [B, H, K]
        kv = kt[..., :, None] * vt[..., None, :]  # [B, H, K, K]
        bonus = uf[None, :, :, None] * kv
        o = jnp.einsum("bhi,bhij->bhj", rt, state + bonus)
        state = wt[..., :, None] * state + kv
        return state, o

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, wf))
    final, o = jax.lax.scan(step, s0, xs)
    return o.transpose(1, 0, 2, 3).astype(r.dtype), final

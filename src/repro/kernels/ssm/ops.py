"""Dispatching wrapper for the Mamba2 SSD chunked scan.

- ``pallas``  TPU kernel (kernel.py); interpret=True on CPU tests;
- ``jnp``     chunk-parallel jnp implementation (same chunked math,
              vmapped over chunks + lax.scan over chunk states) — used for
              dry-run lowering;
- ``ref``     exact per-token recurrence (ref.py).

Also ``ssd_decode_step`` — O(1) single-token state update for serving.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import ssd_pallas, LOG_A_MIN


@functools.partial(jax.jit, static_argnames=("block_t",))
def ssd_jnp(x, dt, a_log, b, c, d, *, block_t: int = 128):
    bs, t, h, p = x.shape
    n = b.shape[-1]
    block_t = min(block_t, t)
    assert t % block_t == 0, (t, block_t)
    nc = t // block_t

    xf = x.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        bs * h, nc, block_t, p)
    dtf = dt.astype(jnp.float32).transpose(0, 2, 1).reshape(
        bs * h, nc, block_t)
    af = -jnp.exp(a_log.astype(jnp.float32))       # [H]
    af = jnp.tile(af, bs)[:, None, None]           # [BH, 1, 1] (b-major flat)
    # NB: flat index is b*h + h_idx -> a per g = a_log[g % h]
    af = -jnp.exp(jnp.tile(a_log.astype(jnp.float32), (bs,)))[:, None, None]
    bf = jnp.repeat(b.astype(jnp.float32).reshape(bs, 1, nc, block_t, n),
                    h, axis=1).reshape(bs * h, nc, block_t, n)
    cf = jnp.repeat(c.astype(jnp.float32).reshape(bs, 1, nc, block_t, n),
                    h, axis=1).reshape(bs * h, nc, block_t, n)
    df = jnp.tile(d.astype(jnp.float32), (bs,))[:, None, None, None]

    loga = jnp.clip(af * dtf, LOG_A_MIN, 0.0)      # [BH, NC, C]
    la = jnp.cumsum(loga, axis=-1)

    tpos = jnp.arange(block_t)
    tril = (tpos[None, :] <= tpos[:, None]).astype(jnp.float32)

    decay = jnp.exp(jnp.minimum(la[..., :, None] - la[..., None, :], 0.0))
    scores = jnp.einsum("gctn,gcsn->gcts", cf, bf) * decay * tril
    xbar = dtf[..., None] * xf
    y_intra = jnp.einsum("gcts,gcsp->gctp", scores, xbar)

    la_end = la[..., -1]                           # [BH, NC]
    b_dec = bf * jnp.exp(jnp.minimum(
        la_end[..., None, None] - la[..., None], 0.0))
    chunk_s = jnp.einsum("gctn,gctp->gcnp", b_dec, xbar)
    a_chunk = jnp.exp(la_end)                      # [BH, NC]

    def step(s, xs):
        a, cs = xs
        out_s = s
        s = a[:, None, None] * s + cs
        return s, out_s

    s0 = jnp.zeros((bs * h, n, p), jnp.float32)
    _, s_in = jax.lax.scan(step, s0, (a_chunk.T, chunk_s.transpose(1, 0, 2, 3)))
    s_in = s_in.transpose(1, 0, 2, 3)
    y_inter = jnp.einsum("gctn,gcnp->gctp", cf * jnp.exp(la)[..., None], s_in)

    y = y_intra + y_inter + df * xf
    return y.reshape(bs, h, t, p).transpose(0, 2, 1, 3).astype(x.dtype)


def ssd(x, dt, a_log, b, c, d, *, impl: str = "auto", block_t: int = 128,
        interpret: bool | None = None):
    """Dispatch: pallas on TPU, chunked jnp otherwise (incl. dry-run)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return ssd_pallas(x, dt, a_log, b, c, d, block_t=block_t,
                          interpret=interpret)
    if impl == "jnp":
        return ssd_jnp(x, dt, a_log, b, c, d, block_t=block_t)
    if impl == "ref":
        return ref.ssd_reference(x, dt, a_log, b, c, d)[0]
    raise ValueError(f"unknown impl {impl!r}")


def ssd_decode_step(state, x, dt, a_log, b, c, d):
    """O(1) single-token update.  state: [B, H, N, P]; x: [B, H, P];
    dt: [B, H]; b/c: [B, N]; a_log/d: [H].  Returns (y [B,H,P], new_state)."""
    sf = state.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(jnp.clip(
        -jnp.exp(a_log.astype(jnp.float32))[None, :] * dtf, LOG_A_MIN, 0.0))
    xbar = dtf[..., None] * xf
    upd = b.astype(jnp.float32)[:, None, :, None] * xbar[:, :, None, :]
    new = decay[..., None, None] * sf + upd
    y = (jnp.einsum("bn,bhnp->bhp", c.astype(jnp.float32), new)
         + d.astype(jnp.float32)[None, :, None] * xf)
    return y.astype(x.dtype), new.astype(state.dtype)

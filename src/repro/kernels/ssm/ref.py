"""Pure-jnp oracle for the Mamba2 SSD (state-space dual) recurrence.

Exact per-token recurrence in fp32 (arXiv:2405.21060, Eq. 16):

    h_t = a_t * h_{t-1} + B_t (dt_t x_t)^T        a_t = exp(A * dt_t), A < 0
    y_t = C_t^T h_t + D * x_t

per head: h [N, P], B/C [N], x [P], a scalar.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference(x, dt, a_log, b, c, d, initial_state=None):
    """x: [B, T, H, P]; dt: [B, T, H]; a_log: [H] (A = -exp(a_log));
    b/c: [B, T, N] (single group, shared across heads); d: [H].

    Returns (y [B, T, H, P], final_state [B, H, N, P]).
    """
    bs, t, h, p = x.shape
    n = b.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    af = -jnp.exp(a_log.astype(jnp.float32))          # [H], negative
    df = d.astype(jnp.float32)

    s0 = (jnp.zeros((bs, h, n, p), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(state, xs):
        xt, dtt, bt, ct = xs                      # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(af[None, :] * dtt)        # [B, H]
        xbar = dtt[..., None] * xt                # [B, H, P]
        upd = bt[:, None, :, None] * xbar[:, :, None, :]   # [B, H, N, P]
        state = decay[..., None, None] * state + upd
        y = jnp.einsum("bn,bhnp->bhp", ct, state) + df[None, :, None] * xt
        return state, y

    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          bf.transpose(1, 0, 2), cf.transpose(1, 0, 2))
    final, y = jax.lax.scan(step, s0, xs)
    return y.transpose(1, 0, 2, 3).astype(x.dtype), final

"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU-native adaptation of the SSD algorithm (Dao & Gu 2024): the sequence is
split into chunks; each (batch x head, chunk) program computes

- the *intra-chunk* quadratic part on the MXU:
  ``Y_intra = ((C B^T) ⊙ exp(La[t]-La[s]) ⊙ (s<=t)) @ Xbar``;
- the *inter-chunk* contribution ``Y_inter = (C ⊙ e^{La}) @ S0``;
- the chunk-state recurrence ``S' = e^{La_end} S0 + (B ⊙ e^{La_end-La})^T Xbar``
  carried in VMEM scratch across the sequential chunk axis.

All decay exponents are differences ``La[t] - La[s]`` with ``s <= t`` and a
monotonically decreasing ``La``, so every exponent is <= 0 — overflow-safe.
Cumulative sums use lower-triangular ones matmuls (MXU) rather than an
unsupported in-kernel scan.

VMEM working set at C=128, N=128, P=64: three [C,N]/[C,P] tiles + the
[C, C] score matrix + the [N, P] state ~ 0.6 MB fp32.

Validated on CPU in interpret mode against ``ref.ssd_reference``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams as _CompilerParams

LOG_A_MIN = -60.0


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, d_ref, y_ref, s_ref, *,
                block_t: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[...].astype(jnp.float32)            # [C, P]
    dt = dt_ref[...].astype(jnp.float32)          # [C, 1]
    a_log = alog_ref[...].astype(jnp.float32)     # [1, 1]
    b = b_ref[...].astype(jnp.float32)            # [C, N]
    c = c_ref[...].astype(jnp.float32)            # [C, N]
    d = d_ref[...].astype(jnp.float32)            # [1, 1]

    loga = jnp.clip(-jnp.exp(a_log[0, 0]) * dt, LOG_A_MIN, 0.0)  # [C, 1]

    cc = block_t
    row = jax.lax.broadcasted_iota(jnp.int32, (cc, cc), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (cc, cc), 1)
    tril_inc = (col <= row).astype(jnp.float32)
    la = tril_inc @ loga                          # [C, 1] inclusive cumsum

    # intra-chunk quadratic part (s <= t, diagonal included)
    decay = jnp.exp(jnp.minimum(la - la.T, 0.0))  # [C, C]
    scores = (c @ b.T) * decay * tril_inc
    xbar = dt * x                                 # [C, P]
    y_intra = scores @ xbar                       # MXU

    # inter-chunk
    s0 = s_ref[...]                               # [N, P]
    y_inter = (c * jnp.exp(la)) @ s0              # MXU [C,N]@[N,P]
    y_ref[...] = (y_intra + y_inter + d[0, 0] * x).astype(y_ref.dtype)

    # state update
    la_end = la[cc - 1, 0]
    b_dec = b * jnp.exp(jnp.minimum(la_end - la, 0.0))
    s_ref[...] = jnp.exp(la_end) * s0 + b_dec.T @ xbar


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def ssd_pallas(x, dt, a_log, b, c, d, *, block_t: int = 128,
               interpret: bool = False):
    """x: [B,T,H,P]; dt: [B,T,H]; a_log/d: [H]; b/c: [B,T,N] -> y [B,T,H,P].

    T must be a multiple of ``block_t``.  Chunk axis is sequential, state in
    VMEM scratch.  B/C are shared across heads (single SSD group).
    """
    bs, t, h, p = x.shape
    n = b.shape[-1]
    block_t = min(block_t, t)
    assert t % block_t == 0, (t, block_t)

    xf = x.transpose(0, 2, 1, 3).reshape(bs * h, t, p)
    dtf = dt.transpose(0, 2, 1).reshape(bs * h, t, 1)
    grid = (bs * h, t // block_t)

    kernel = functools.partial(_ssd_kernel, block_t=block_t)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_t, p), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((None, block_t, 1), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, 1), lambda g, ci, h=h: (g % h, 0)),
            pl.BlockSpec((None, block_t, n), lambda g, ci, h=h: (g // h, ci, 0)),
            pl.BlockSpec((None, block_t, n), lambda g, ci, h=h: (g // h, ci, 0)),
            pl.BlockSpec((1, 1), lambda g, ci, h=h: (g % h, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_t, p), lambda g, ci: (g, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bs * h, t, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xf, dtf, a_log.reshape(h, 1), b, c, d.reshape(h, 1))
    return y.reshape(bs, h, t, p).transpose(0, 2, 1, 3)

"""Checkpoint-restart: async (thread-offloaded) atomic pytree snapshots.

Fault-tolerance contract:

- **atomicity**: write to ``<dir>/tmp.<step>`` then ``os.replace`` to
  ``step_<n>`` — a crash mid-write never corrupts the restore set;
- **async**: ``CheckpointManager.save`` snapshots device arrays to host
  (blocking only for the device->host copy), then a worker thread does
  the serialisation/IO while training continues;
- **resume-from-latest**: ``latest_step`` + ``restore_pytree`` restore
  both params and optimizer state, re-sharding onto the current mesh
  (elastic restart: the surviving-device mesh may differ from the one
  that wrote the checkpoint).
"""

from __future__ import annotations

import concurrent.futures as cf
import json
import os
import re

import jax
import numpy as np


def _leaf_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_pytree(tree, directory: str, step: int):
    """Synchronous atomic save."""
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = _leaf_paths(tree)
    host = [np.asarray(x) for x in leaves]
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:08d}.npz")
    np.savez(tmp, **{f"leaf_{i}": a for i, a in enumerate(host)})
    os.replace(tmp + ".npz", final)
    with open(os.path.join(directory, f"meta_{step:08d}.json"), "w") as f:
        json.dump({"step": step, "num_leaves": len(host)}, f)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_latest(template, directory: str, shardings=None):
    """Restore the newest *complete* checkpoint, or ``None`` when the
    directory holds none.  Only ``step_<n>.npz`` files count — a crash
    mid-write leaves a ``tmp.<step>`` artifact (and possibly a stale
    ``tmp.<step>.npz`` never renamed), which must never be restored; a
    finalized-but-unreadable archive falls back to the next-newest step.
    Returns ``(step, tree)``."""
    if not os.path.isdir(directory):
        return None
    steps = sorted((int(m.group(1)) for f in os.listdir(directory)
                    if (m := re.match(r"step_(\d+)\.npz$", f))),
                   reverse=True)
    for step in steps:
        try:
            return step, restore_pytree(template, directory, step,
                                        shardings=shardings)
        except (OSError, ValueError, KeyError):
            continue  # truncated/corrupt archive: try the older snapshot
    return None


def restore_pytree(template, directory: str, step: int, shardings=None):
    """Restore into the structure of ``template``; if ``shardings`` is
    given, place each leaf with it (elastic re-sharding)."""
    leaves, treedef = jax.tree.flatten(template)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    with np.load(path) as z:
        host = [z[f"leaf_{i}"] for i in range(len(leaves))]
    if shardings is not None:
        sh_leaves = jax.tree.flatten(shardings)[0]
        out = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
    else:
        out = [jax.device_put(h.astype(l.dtype) if hasattr(l, "dtype") else h)
               for h, l in zip(host, leaves)]
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Async manager: save every ``interval`` steps, keep ``max_keep``."""

    def __init__(self, directory: str, interval: int = 100,
                 max_keep: int = 3):
        self.directory = directory
        self.interval = interval
        self.max_keep = max_keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: cf.Future | None = None

    def maybe_save(self, tree, step: int) -> bool:
        if step % self.interval != 0:
            return False
        self.wait()
        # snapshot to host synchronously (cheap), serialise in background
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]
        snap = jax.tree.unflatten(treedef, host)
        self._pending = self._pool.submit(self._save_and_gc, snap, step)
        return True

    def _save_and_gc(self, snap, step):
        save_pytree(snap, self.directory, step)
        steps = sorted(
            int(m.group(1)) for f in os.listdir(self.directory)
            if (m := re.match(r"step_(\d+)\.npz$", f)))
        for s in steps[:-self.max_keep]:
            for pat in (f"step_{s:08d}.npz", f"meta_{s:08d}.json"):
                try:
                    os.remove(os.path.join(self.directory, pat))
                except FileNotFoundError:
                    pass

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def close(self):
        self.wait()
        self._pool.shutdown()

"""Deterministic sharded synthetic token pipeline.

Design goals of a production input pipeline, kept:

- **determinism across restarts**: batch ``i`` is a pure function of
  (seed, i) — resuming from a checkpoint at step i reproduces the exact
  token stream without replaying the pipeline;
- **per-DP-rank sharding**: each data-parallel rank materialises only its
  slice; ``make_global_batch`` assembles a globally-sharded array with
  ``jax.make_array_from_callback`` so no host ever holds the full batch;
- **double buffering**: an async prefetch thread keeps one batch ahead.

Tokens follow a Zipfian marginal (vocab realism for embedding-gather perf)
and labels are the next-token shift.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class SyntheticTokenDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch_slice(self, step: int, lo: int, hi: int) -> dict:
        """Rows [lo, hi) of global batch ``step``.

        Seeded PER ROW, so any sharding of the batch — including a
        different mesh after an elastic restart — sees identical data."""
        rows = []
        for r in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, r]))
            rows.append(rng.zipf(self.zipf_a, size=self.seq_len + 1))
        toks = (np.stack(rows) - 1) % self.vocab_size
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def host_batch(self, step: int) -> dict:
        return self.batch_slice(step, 0, self.global_batch)


def make_global_batch(ds: SyntheticTokenDataset, step: int, mesh,
                      batch_axes=("pod", "data")) -> dict:
    """Assemble a globally-sharded device array; each addressable shard is
    filled from the deterministic per-rank slice only."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    sharding = NamedSharding(mesh, P(axes if len(axes) > 1 else
                                     (axes[0] if axes else None)))
    shape = (ds.global_batch, ds.seq_len)

    def cb(key):
        def make(index):
            lo = index[0].start or 0
            hi = (index[0].stop if index[0].stop is not None
                  else ds.global_batch)
            return ds.batch_slice(step, lo, hi)[key]

        return jax.make_array_from_callback(shape, sharding, make)

    return {"tokens": cb("tokens"), "labels": cb("labels")}


class Prefetcher:
    """One-batch-ahead async prefetch (double buffering)."""

    def __init__(self, fn, start_step: int = 0, depth: int = 1):
        self._fn = fn
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._loop, daemon=True)
        self._t.start()

    def _loop(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self._q.put((s, self._fn(s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._t.join(timeout=2)

from .pipeline import SyntheticTokenDataset, make_global_batch

__all__ = ["SyntheticTokenDataset", "make_global_batch"]
